//! `sfc bench` — the conv perf-snapshot harness.
//!
//! Measures every supporting engine on a fixed set of ResNet/VGG-scale
//! layer shapes — dense plus grouped/depthwise (the MobileNet-block
//! workloads) — through the steady-state datapath (pre-packed weights +
//! `run_packed_into` over a reused [`Workspace`], exactly what a serving
//! worker runs), prints a table and — with `--json` — writes a
//! machine-readable `BENCH_conv.json` so the perf trajectory of the
//! repo is tracked across PRs: per shape and engine, ns/call, GFLOP/s
//! (2·MACs / time) and the workspace heap-fallback count during the
//! timed window (0 = the zero-alloc property held). The snapshot also
//! records which dispatch arm ran (`kernel`: `"avx2" | "neon" |
//! "scalar"`, see [`crate::linalg::simd`]) and — when a SIMD kernel is
//! active — a scalar-vs-SIMD `speedup` block measured in-process by
//! re-running the dense 3×3 GEMM-backed engines with dispatch pinned to
//! scalar. Since v5 the snapshot also records the GEMM `threads` count,
//! the active Mc/Kc/Nc `blocking`, and a single-vs-multi-thread
//! `scaling` block measured by pinning the thread count to 1. The JSON
//! format is versioned ([`BENCH_SCHEMA_VERSION`]) and documented in
//! ENGINE.md §"BENCH_conv.json schema".

use crate::engine::{default_selector, ConvDesc, ConvPlan, PackedWeights, QuantSpec, Workspace};
use crate::linalg::simd::{self, Kernel};
use crate::nn::graph::Op;
use crate::nn::model::{mobilenet_cfg, mobilenet_random};
use crate::nn::{Model, Tensor};
use crate::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use crate::quant::{quantize_model, QuantConfig};
use crate::util::Pcg32;
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// The engines every snapshot covers (where they support the shape).
const ENGINES: [&str; 9] = [
    "direct",
    "im2col-gemm",
    "Wino(4x4,3x3)",
    "SFC-6(6x6,3x3)",
    "SFC-6(7x7,3x3)",
    "FFT",
    "FFT-tiled",
    "NTT",
    "NTT-tiled",
];

/// The GEMM-backed engines the scalar-vs-SIMD speedup block measures on
/// the dense 3×3 shapes (plus the int8 SFC executor in full mode).
const SPEEDUP_ENGINES: [&str; 4] =
    ["im2col-gemm", "Wino(4x4,3x3)", "SFC-6(6x6,3x3)", "SFC-6(7x7,3x3)"];

/// One measured (shape, engine) cell.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// shape label (`-dw` = depthwise, `-gN` = grouped)
    pub shape: String,
    /// engine name (`-int8` suffix = the quantized executor)
    pub engine: String,
    /// median wall time of one call
    pub ns_per_call: f64,
    /// 2·MACs / ns_per_call (group-aware MACs)
    pub gflops: f64,
    /// the plan's reported scratch demand
    pub workspace_bytes: usize,
    /// heap fallbacks observed during the timed window (0 = zero-alloc)
    pub ws_heap_allocs_steady: u64,
}

/// One scalar-vs-SIMD comparison cell (dense 3×3 shapes only).
#[derive(Clone, Debug)]
pub struct SpeedupRow {
    /// shape label
    pub shape: String,
    /// engine name
    pub engine: String,
    /// median ns/call with dispatch pinned to the scalar kernels
    pub scalar_ns_per_call: f64,
    /// median ns/call under the detected SIMD kernel
    pub ns_per_call: f64,
    /// `scalar_ns_per_call / ns_per_call`
    pub speedup: f64,
}

/// One single-vs-multi-thread comparison cell (dense 3×3 shapes only):
/// the same engine timed with the GEMM macro-kernel pinned to one
/// thread and under the process thread count.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    /// shape label
    pub shape: String,
    /// engine name
    pub engine: String,
    /// median ns/call with the thread count pinned to 1
    pub single_thread_ns_per_call: f64,
    /// median ns/call under the process thread count
    pub ns_per_call: f64,
    /// `single_thread_ns_per_call / ns_per_call`
    pub scaling: f64,
}

/// Benchmark configuration (CLI flags).
pub struct BenchCfg {
    /// timed iterations per cell
    pub iters: usize,
    /// unmeasured warm-up iterations per cell
    pub warmup: usize,
    /// restrict to the smallest shape + float engines (CI smoke)
    pub quick: bool,
}

fn shapes(quick: bool) -> Vec<(&'static str, ConvDesc)> {
    let mut v = vec![
        ("28x28x32->32", ConvDesc::new(1, 32, 32, 28, 28, 3, 1, 1)),
        // depthwise 3×3 (groups == ic): the MobileNet-block workhorse
        ("28x28x32-dw", ConvDesc::new(1, 32, 32, 28, 28, 3, 1, 1).with_groups(32)),
    ];
    if !quick {
        v.push(("14x14x128->128", ConvDesc::new(1, 128, 128, 14, 14, 3, 1, 1)));
        v.push(("56x56x64->64", ConvDesc::new(1, 64, 64, 56, 56, 3, 1, 1)));
        v.push(("56x56x64-dw", ConvDesc::new(1, 64, 64, 56, 56, 3, 1, 1).with_groups(64)));
        v.push(("14x14x64-g4", ConvDesc::new(1, 64, 64, 14, 14, 3, 1, 1).with_groups(4)));
        // large-kernel large-image row (the examples/large_kernel.rs
        // geometry): the whole-image FFT/NTT engines decline it, the
        // overlap-save tiled engines carry it
        v.push(("192x192x8-r11", ConvDesc::new(1, 8, 8, 192, 192, 11, 1, 5)));
        // dilated 3×3: only the spatial engines (direct/im2col) take it
        v.push(("28x28x32-d2", ConvDesc::new(1, 32, 32, 28, 28, 3, 1, 2).with_dilation(2)));
    }
    v
}

fn median_ns(samples: &mut Vec<f64>) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Deterministic workload tensors for one descriptor.
fn workload(desc: &ConvDesc, rng: &mut Pcg32) -> (Tensor, Tensor) {
    let mut x = Tensor::zeros(&[desc.batch, desc.ic, desc.h, desc.w]);
    rng.fill_gaussian(&mut x.data, 1.0);
    let mut w = Tensor::zeros(&[desc.oc, desc.ic / desc.groups, desc.r, desc.r]);
    rng.fill_gaussian(&mut w.data, 0.2);
    (x, w)
}

/// Time a float plan on the steady-state datapath: weights pre-packed
/// once (plan time), then warm-up + timed `run_packed_into` calls over
/// one reused workspace. Returns (median ns/call, steady heap allocs).
fn time_float_plan(plan: &Arc<ConvPlan>, x: &Tensor, w: &Tensor, cfg: &BenchCfg) -> (f64, u64) {
    let packed = PackedWeights::pack(plan, w);
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(&plan.out_dims(x, w));
    for _ in 0..cfg.warmup.max(1) {
        plan.run_packed_into(x, w, &packed, &[], &mut ws, &mut out);
    }
    let allocs_before = ws.heap_allocs();
    let mut samples = Vec::with_capacity(cfg.iters.max(1));
    for _ in 0..cfg.iters.max(1) {
        let t0 = Instant::now();
        plan.run_packed_into(x, w, &packed, &[], &mut ws, &mut out);
        std::hint::black_box(&out.data);
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    (median_ns(&mut samples), ws.heap_allocs() - allocs_before)
}

/// Time a quantized layer on the steady-state datapath (its packed
/// panels were built at construction).
fn time_qconv(q: &QConvLayer, x: &Tensor, cfg: &BenchCfg) -> (f64, u64) {
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(&q.out_dims(x));
    for _ in 0..cfg.warmup.max(1) {
        q.forward_into(x, &mut ws, &mut out);
    }
    let allocs_before = ws.heap_allocs();
    let mut samples = Vec::with_capacity(cfg.iters.max(1));
    for _ in 0..cfg.iters.max(1) {
        let t0 = Instant::now();
        q.forward_into(x, &mut ws, &mut out);
        std::hint::black_box(&out.data);
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    (median_ns(&mut samples), ws.heap_allocs() - allocs_before)
}

/// Group-aware conv MACs of a whole model for one image, read from the
/// conv nodes' plan descriptors.
fn model_macs(m: &Model) -> u64 {
    m.nodes
        .iter()
        .filter_map(|n| match &n.op {
            Op::Conv { plan, .. } => Some(plan.desc.macs() / plan.desc.batch.max(1) as u64),
            _ => None,
        })
        .sum()
}

/// End-to-end compiled-model rows (schema v4): the mini MobileNet
/// through `Model::forward_ws` over one reused workspace, once
/// float-compiled (fused epilogues + pre-packed weights) and once
/// int8-compiled (spatial int8 PTQ + the graph compiler's int8
/// dataflow, so consecutive quantized convs exchange int8 codes with
/// no f32 round trip). The shape label carries the batch; gflops uses
/// the group-aware conv MACs of the whole stack.
pub fn run_model_e2e(cfg: &BenchCfg) -> Result<Vec<BenchRow>> {
    let batch = 2usize;
    let mut rng = Pcg32::seeded(0xE2E);
    let mut x = Tensor::zeros(&[batch, 3, 32, 32]);
    rng.fill_gaussian(&mut x.data, 1.0);
    let mut rows = Vec::new();
    for (engine, int8) in [("e2e-f32-compiled", false), ("e2e-int8-compiled", true)] {
        let mut m = mobilenet_random(&mobilenet_cfg(), 11, 10);
        if int8 {
            // plain max-abs calibration: the bench measures the
            // datapath, not the PTQ quality
            let mut qcfg = QuantConfig::direct_default(8);
            qcfg.adaquant = false;
            quantize_model(&mut m, &x, &qcfg);
        }
        let flops = 2.0 * model_macs(&m) as f64 * batch as f64;
        m.compile();
        m.prepack_weights();
        let mut ws = Workspace::new();
        for _ in 0..cfg.warmup.max(1) {
            let y = m.forward_ws(&x, &mut ws);
            ws.give_f32(y.data);
        }
        let allocs_before = ws.heap_allocs();
        let mut samples = Vec::with_capacity(cfg.iters.max(1));
        for _ in 0..cfg.iters.max(1) {
            let t0 = Instant::now();
            let y = m.forward_ws(&x, &mut ws);
            std::hint::black_box(&y.data);
            ws.give_f32(y.data);
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let ns = median_ns(&mut samples);
        let row = BenchRow {
            shape: format!("mobilenet-32x32-b{batch}"),
            engine: engine.to_string(),
            ns_per_call: ns,
            gflops: flops / ns.max(1.0),
            workspace_bytes: 0,
            ws_heap_allocs_steady: ws.heap_allocs() - allocs_before,
        };
        println!(
            "  {:<18} {:>12.0} ns/model {:>8.2} GFLOP/s  steady allocs {}",
            row.engine, row.ns_per_call, row.gflops, row.ws_heap_allocs_steady
        );
        rows.push(row);
    }
    Ok(rows)
}

/// Run the snapshot; returns every measured row.
pub fn run_bench(cfg: &BenchCfg) -> Result<Vec<BenchRow>> {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(42);
    let mut rows = Vec::new();
    for (label, desc) in shapes(cfg.quick) {
        let (x, w) = workload(&desc, &mut rng);
        let flops = 2.0 * desc.macs() as f64;
        println!("\n=== {label} ({:.1} MMACs) ===", desc.macs() as f64 / 1e6);
        for name in ENGINES {
            let Ok(plan) = sel.plan_named(name, &desc) else {
                println!("  {name:<18} (unsupported at this shape)");
                continue;
            };
            let (ns, steady_allocs) = time_float_plan(&plan, &x, &w, cfg);
            let row = BenchRow {
                shape: label.to_string(),
                engine: name.to_string(),
                ns_per_call: ns,
                gflops: flops / ns.max(1.0),
                workspace_bytes: plan.workspace_bytes(),
                ws_heap_allocs_steady: steady_allocs,
            };
            println!(
                "  {:<18} {:>12.0} ns/call {:>8.2} GFLOP/s  ws {:>8.1} KB  steady allocs {}",
                row.engine,
                row.ns_per_call,
                row.gflops,
                row.workspace_bytes as f64 / 1024.0,
                row.ws_heap_allocs_steady
            );
            rows.push(row);
        }
        if !cfg.quick {
            // int8 transform-domain SFC through the same reused-workspace path
            let qdesc = desc.with_quant(QuantSpec::transform_default(8));
            if let Ok(qplan) = sel.plan_named("SFC-6(7x7,3x3)", &qdesc) {
                let maxima = collect_act_maxima(&x, qplan.fast_plan().unwrap(), desc.pad);
                let q = QConvLayer::from_plan(qplan, &w, vec![], &QCalib::TransformMaxima(&maxima));
                let (ns, steady_allocs) = time_qconv(&q, &x, cfg);
                let row = BenchRow {
                    shape: label.to_string(),
                    engine: "SFC-6(7x7,3x3)-int8".to_string(),
                    ns_per_call: ns,
                    gflops: flops / ns.max(1.0),
                    workspace_bytes: 0,
                    ws_heap_allocs_steady: steady_allocs,
                };
                println!(
                    "  {:<18} {:>12.0} ns/call {:>8.2} GFLOP/s  (int8 ⊙)      steady allocs {}",
                    row.engine, row.ns_per_call, row.gflops, row.ws_heap_allocs_steady
                );
                rows.push(row);
            }
        }
    }
    if !cfg.quick {
        // end-to-end compiled-model rows (f32 + int8 MobileNet through
        // the graph compiler) — the saved passes of the fused/int8
        // dataflow show up in the perf trajectory
        println!("\n=== mobilenet e2e (compiled graph, batch 2) ===");
        rows.extend(run_model_e2e(cfg)?);
    }
    Ok(rows)
}

/// Measure the scalar-vs-SIMD speedup block: the dense 3×3 shapes ×
/// the GEMM-backed engines, each cell timed under the detected kernel
/// and again with dispatch pinned to scalar
/// ([`crate::linalg::simd::set_kernel_override`]). Empty when the
/// process is already running the scalar kernels — the snapshot then
/// *is* the scalar baseline.
pub fn run_speedup(cfg: &BenchCfg) -> Result<Vec<SpeedupRow>> {
    let active = simd::active_kernel();
    if active == Kernel::Scalar {
        return Ok(Vec::new());
    }
    let sel = default_selector();
    let mut rng = Pcg32::seeded(42);
    let mut rows = Vec::new();
    for (label, desc) in shapes(cfg.quick) {
        if desc.groups != 1 || desc.r != 3 {
            continue; // the acceptance metric tracks the dense 3×3 shapes
        }
        let (x, w) = workload(&desc, &mut rng);
        for name in SPEEDUP_ENGINES {
            let Ok(plan) = sel.plan_named(name, &desc) else { continue };
            let (simd_ns, _) = time_float_plan(&plan, &x, &w, cfg);
            simd::set_kernel_override(Some(Kernel::Scalar));
            let (scalar_ns, _) = time_float_plan(&plan, &x, &w, cfg);
            simd::set_kernel_override(None);
            rows.push(SpeedupRow {
                shape: label.to_string(),
                engine: name.to_string(),
                scalar_ns_per_call: scalar_ns,
                ns_per_call: simd_ns,
                speedup: scalar_ns / simd_ns.max(1.0),
            });
        }
        if !cfg.quick {
            // the quantized SFC executor: int8 GEMM + quantize loops
            let qdesc = desc.with_quant(QuantSpec::transform_default(8));
            if let Ok(qplan) = sel.plan_named("SFC-6(7x7,3x3)", &qdesc) {
                let maxima = collect_act_maxima(&x, qplan.fast_plan().unwrap(), desc.pad);
                let q = QConvLayer::from_plan(qplan, &w, vec![], &QCalib::TransformMaxima(&maxima));
                let (simd_ns, _) = time_qconv(&q, &x, cfg);
                simd::set_kernel_override(Some(Kernel::Scalar));
                let (scalar_ns, _) = time_qconv(&q, &x, cfg);
                simd::set_kernel_override(None);
                rows.push(SpeedupRow {
                    shape: label.to_string(),
                    engine: "SFC-6(7x7,3x3)-int8".to_string(),
                    scalar_ns_per_call: scalar_ns,
                    ns_per_call: simd_ns,
                    speedup: scalar_ns / simd_ns.max(1.0),
                });
            }
        }
    }
    Ok(rows)
}

/// Measure the single-vs-multi-thread scaling block: the dense 3×3
/// shapes × the GEMM-backed engines, each cell timed under the process
/// thread count ([`crate::util::par::num_threads`]) and again with the
/// count pinned to 1 ([`crate::util::par::set_thread_override`]). Empty
/// when the process already runs single-threaded — the snapshot then
/// *is* the single-thread baseline. Note the per-element k-accumulation
/// order is thread-count invariant, so both cells compute bit-identical
/// outputs; only the wall time moves.
pub fn run_scaling(cfg: &BenchCfg) -> Result<Vec<ScalingRow>> {
    use crate::util::par;
    if par::num_threads() <= 1 {
        return Ok(Vec::new());
    }
    let sel = default_selector();
    let mut rng = Pcg32::seeded(42);
    let mut rows = Vec::new();
    for (label, desc) in shapes(cfg.quick) {
        if desc.groups != 1 || desc.r != 3 {
            continue; // the acceptance metric tracks the dense 3×3 shapes
        }
        let (x, w) = workload(&desc, &mut rng);
        for name in SPEEDUP_ENGINES {
            let Ok(plan) = sel.plan_named(name, &desc) else { continue };
            let (multi_ns, _) = time_float_plan(&plan, &x, &w, cfg);
            par::set_thread_override(Some(1));
            let (single_ns, _) = time_float_plan(&plan, &x, &w, cfg);
            par::set_thread_override(None);
            rows.push(ScalingRow {
                shape: label.to_string(),
                engine: name.to_string(),
                single_thread_ns_per_call: single_ns,
                ns_per_call: multi_ns,
                scaling: single_ns / multi_ns.max(1.0),
            });
        }
    }
    Ok(rows)
}

/// The BENCH_conv.json format revision, emitted as `schema_version`.
/// Bump on any field/semantics change; the schema itself is documented
/// in ENGINE.md §"BENCH_conv.json schema".
/// v2: added `schema_version` itself + grouped/depthwise shape rows.
/// v3: added the top-level `kernel` dispatch-arm field and the
/// scalar-vs-SIMD `speedup` block; float cells measure the pre-packed
/// `run_packed_into` datapath.
/// v4: added the end-to-end compiled-model rows (shape
/// `mobilenet-32x32-b2`, engines `e2e-f32-compiled` /
/// `e2e-int8-compiled`): whole-model `Model::forward_ws` of the
/// pass-pipeline-compiled graph, int8 row running the requantized
/// int8 dataflow between consecutive quantized convs.
/// v5: added the top-level `threads` field (GEMM worker-thread count),
/// the `blocking` object (the active Mc/Kc/Nc cache-blocking of the
/// dispatched kernel) and the single-vs-multi-thread `scaling` block
/// next to the scalar-vs-SIMD `speedup` block.
/// v6: engine axis extended with the overlap-save tiled
/// frequency-domain engines (`FFT-tiled` / `NTT-tiled`) and two new
/// full-mode shape rows: `192x192x8-r11` (large kernel + large image;
/// whole-image FFT/NTT decline it) and `28x28x32-d2` (dilation 2;
/// direct/im2col only).
/// v7: added the top-level `pool` object — the persistent
/// executor-pool gauges at snapshot time (`workers` resident,
/// lifetime `tasks` / `steals` / `spawn_avoided` counters, see
/// [`crate::util::pool::gauges`]) — the observable proof that parallel
/// regions ran as pool tasks instead of spawned threads.
pub const BENCH_SCHEMA_VERSION: u32 = 7;

/// Serialize rows as the BENCH_conv.json snapshot (no serde in this
/// image — the format is flat enough to emit by hand).
pub fn to_json(
    rows: &[BenchRow],
    speedups: &[SpeedupRow],
    scalings: &[ScalingRow],
    kernel: &str,
    threads: usize,
    blocking: crate::linalg::gemm::Blocking,
) -> String {
    let mut s = String::from("{\n  \"bench\": \"conv\",\n");
    s.push_str(&format!("  \"schema_version\": {BENCH_SCHEMA_VERSION},\n"));
    s.push_str(&format!("  \"kernel\": \"{kernel}\",\n"));
    s.push_str(&format!("  \"threads\": {threads},\n"));
    s.push_str(&format!(
        "  \"blocking\": {{\"mc\": {}, \"kc\": {}, \"nc\": {}}},\n",
        blocking.mc, blocking.kc, blocking.nc
    ));
    let pg = crate::util::pool::gauges();
    s.push_str(&format!(
        "  \"pool\": {{\"workers\": {}, \"steals\": {}, \"spawn_avoided\": {}}},\n",
        pg.workers, pg.steals, pg.spawn_avoided
    ));
    s.push_str(concat!(
        "  \"units\": {\"time\": \"ns/call\", \"rate\": \"GFLOP/s\"},\n",
        "  \"results\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            concat!(
                "    {{\"shape\": \"{}\", \"engine\": \"{}\", \"ns_per_call\": {:.1}, ",
                "\"gflops\": {:.4}, \"workspace_bytes\": {}, ",
                "\"ws_heap_allocs_steady\": {}}}{}\n"
            ),
            r.shape,
            r.engine,
            r.ns_per_call,
            r.gflops,
            r.workspace_bytes,
            r.ws_heap_allocs_steady,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n  \"speedup\": [\n");
    for (i, r) in speedups.iter().enumerate() {
        s.push_str(&format!(
            concat!(
                "    {{\"shape\": \"{}\", \"engine\": \"{}\", ",
                "\"scalar_ns_per_call\": {:.1}, \"ns_per_call\": {:.1}, ",
                "\"speedup\": {:.3}}}{}\n"
            ),
            r.shape,
            r.engine,
            r.scalar_ns_per_call,
            r.ns_per_call,
            r.speedup,
            if i + 1 == speedups.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n  \"scaling\": [\n");
    for (i, r) in scalings.iter().enumerate() {
        s.push_str(&format!(
            concat!(
                "    {{\"shape\": \"{}\", \"engine\": \"{}\", ",
                "\"single_thread_ns_per_call\": {:.1}, \"ns_per_call\": {:.1}, ",
                "\"scaling\": {:.3}}}{}\n"
            ),
            r.shape,
            r.engine,
            r.single_thread_ns_per_call,
            r.ns_per_call,
            r.scaling,
            if i + 1 == scalings.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// `sfc bench [--json] [--out PATH] [--iters N] [--warmup N] [--quick]`.
pub fn cmd_bench(cfg: &BenchCfg, json: bool, out_path: &str) -> Result<()> {
    let kernel = simd::kernel_name();
    let threads = crate::util::par::num_threads();
    let blocking = crate::linalg::gemm::active_blocking();
    println!("kernel dispatch: {kernel} (SFC_FORCE_SCALAR=1 pins scalar)");
    println!(
        "threads: {threads} (SFC_THREADS pins) · blocking mc={} kc={} nc={}",
        blocking.mc, blocking.kc, blocking.nc
    );
    let rows = run_bench(cfg)?;
    let speedups = run_speedup(cfg)?;
    if !speedups.is_empty() {
        println!("\nscalar → {kernel} speedup (dense 3×3 shapes):");
        for r in &speedups {
            println!(
                "  {:<16} {:<20} {:>10.0} → {:>10.0} ns/call  {:.2}x",
                r.shape, r.engine, r.scalar_ns_per_call, r.ns_per_call, r.speedup
            );
        }
    }
    let scalings = run_scaling(cfg)?;
    if !scalings.is_empty() {
        println!("\n1 thread → {threads} threads scaling (dense 3×3 shapes):");
        for r in &scalings {
            println!(
                "  {:<16} {:<20} {:>10.0} → {:>10.0} ns/call  {:.2}x",
                r.shape, r.engine, r.single_thread_ns_per_call, r.ns_per_call, r.scaling
            );
        }
    }
    let pg = crate::util::pool::gauges();
    println!(
        "\npool: {} workers · {} tasks · {} steals · {} spawns avoided",
        pg.workers, pg.tasks, pg.steals, pg.spawn_avoided
    );
    if json {
        let body = to_json(&rows, &speedups, &scalings, kernel, threads, blocking);
        std::fs::write(out_path, &body).with_context(|| format!("write {out_path}"))?;
        println!("\nwrote {out_path} ({} rows)", rows.len());
    }
    // The headline the snapshot exists to track: GEMM-cored fast conv vs
    // the direct baseline on the 3x3 shapes.
    for (label, _) in shapes(cfg.quick) {
        let direct = rows.iter().find(|r| r.shape == label && r.engine == "direct");
        let best_fast = rows
            .iter()
            .filter(|r| {
                r.shape == label
                    && (r.engine.starts_with("SFC") || r.engine.starts_with("Wino"))
            })
            .min_by(|a, b| a.ns_per_call.partial_cmp(&b.ns_per_call).unwrap());
        if let (Some(d), Some(f)) = (direct, best_fast) {
            println!(
                "{label}: best fast engine {} at {:.2}x vs direct",
                f.engine,
                d.ns_per_call / f.ns_per_call
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_is_valid_enough() {
        let rows = vec![BenchRow {
            shape: "s".into(),
            engine: "direct".into(),
            ns_per_call: 12.5,
            gflops: 1.25,
            workspace_bytes: 64,
            ws_heap_allocs_steady: 0,
        }];
        let speedups = vec![SpeedupRow {
            shape: "s".into(),
            engine: "im2col-gemm".into(),
            scalar_ns_per_call: 25.0,
            ns_per_call: 12.5,
            speedup: 2.0,
        }];
        let scalings = vec![ScalingRow {
            shape: "s".into(),
            engine: "im2col-gemm".into(),
            single_thread_ns_per_call: 50.0,
            ns_per_call: 12.5,
            scaling: 4.0,
        }];
        let blocking = crate::linalg::gemm::Blocking { mc: 64, kc: 512, nc: 256 };
        let j = to_json(&rows, &speedups, &scalings, "avx2", 4, blocking);
        assert!(j.contains("\"bench\": \"conv\""));
        assert!(j.contains(&format!("\"schema_version\": {BENCH_SCHEMA_VERSION}")));
        assert!(j.contains("\"kernel\": \"avx2\""));
        assert!(j.contains("\"threads\": 4"));
        assert!(j.contains("\"blocking\": {\"mc\": 64, \"kc\": 512, \"nc\": 256}"));
        assert!(j.contains("\"pool\": {\"workers\": "), "pool gauges block present: {j}");
        assert!(j.contains("\"spawn_avoided\": "), "{j}");
        assert!(j.contains("\"engine\": \"direct\""));
        assert!(j.contains("\"ns_per_call\": 12.5"));
        assert!(j.contains("\"speedup\": 2.000"));
        assert!(j.contains("\"scaling\": 4.000"));
        assert!(j.contains("\"single_thread_ns_per_call\": 50.0"));
        assert!(!j.contains(",\n  ]"), "no trailing comma before an array close");
        // empty speedup/scaling blocks (scalar or 1-core host) still
        // close their arrays
        let j = to_json(&rows, &[], &[], "scalar", 1, blocking);
        assert!(j.contains("\"speedup\": [\n  ]"), "{j}");
        assert!(j.contains("\"scaling\": [\n  ]"), "{j}");
    }

    #[test]
    fn quick_bench_runs_and_is_alloc_free_in_steady_state() {
        let rows = run_bench(&BenchCfg { iters: 1, warmup: 1, quick: true }).unwrap();
        assert!(rows.iter().any(|r| r.engine == "direct"));
        assert!(rows.iter().any(|r| r.engine.starts_with("SFC")));
        for r in &rows {
            assert!(r.ns_per_call > 0.0, "{}", r.engine);
            assert_eq!(r.ws_heap_allocs_steady, 0, "{} must be zero-alloc after warm-up", r.engine);
        }
        // the depthwise shape is measured, and only by engines that
        // claim grouped support (no whole-image FFT/NTT rows)
        let dw: Vec<_> = rows.iter().filter(|r| r.shape == "28x28x32-dw").collect();
        assert!(dw.iter().any(|r| r.engine == "direct"));
        assert!(dw.iter().any(|r| r.engine.starts_with("SFC") || r.engine.starts_with("Wino")));
        assert!(dw.iter().all(|r| r.engine != "FFT" && r.engine != "NTT"));
    }

    #[test]
    fn speedup_block_covers_dense_3x3_when_simd_is_active() {
        // run_speedup toggles the process-global kernel override
        let _g = crate::linalg::simd::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let cfg = BenchCfg { iters: 1, warmup: 1, quick: true };
        let speedups = run_speedup(&cfg).unwrap();
        if crate::linalg::simd::active_kernel() == Kernel::Scalar {
            assert!(speedups.is_empty(), "scalar host: the snapshot is the baseline");
        } else {
            assert!(!speedups.is_empty(), "SIMD host must record the speedup block");
            for r in &speedups {
                assert_eq!(r.shape, "28x28x32->32", "quick mode: dense 3×3 only");
                assert!(r.scalar_ns_per_call > 0.0 && r.ns_per_call > 0.0, "{}", r.engine);
            }
        }
    }

    #[test]
    fn scaling_block_covers_dense_3x3_on_multicore_hosts() {
        // run_scaling toggles the process-global thread override
        let _g = crate::linalg::simd::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let cfg = BenchCfg { iters: 1, warmup: 1, quick: true };
        let scalings = run_scaling(&cfg).unwrap();
        if crate::util::par::num_threads() <= 1 {
            assert!(scalings.is_empty(), "1-core host: the snapshot is the baseline");
        } else {
            assert!(!scalings.is_empty(), "multi-core host must record the scaling block");
            for r in &scalings {
                assert_eq!(r.shape, "28x28x32->32", "quick mode: dense 3×3 only");
                assert!(
                    r.single_thread_ns_per_call > 0.0 && r.ns_per_call > 0.0,
                    "{}",
                    r.engine
                );
                assert!(r.scaling > 0.0, "{}", r.engine);
            }
        }
    }

    #[test]
    fn model_e2e_rows_measure_compiled_f32_and_int8() {
        let rows = run_model_e2e(&BenchCfg { iters: 1, warmup: 1, quick: true }).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().any(|r| r.engine == "e2e-f32-compiled"));
        assert!(rows.iter().any(|r| r.engine == "e2e-int8-compiled"));
        for r in &rows {
            assert!(r.ns_per_call > 0.0 && r.gflops > 0.0, "{}", r.engine);
            assert_eq!(
                r.ws_heap_allocs_steady, 0,
                "{} must be alloc-free after warm-up",
                r.engine
            );
            assert_eq!(r.shape, "mobilenet-32x32-b2");
        }
    }

    #[test]
    fn default_bench_shapes_cover_grouped_and_depthwise() {
        let grouped = shapes(false)
            .iter()
            .filter(|(_, d)| d.groups > 1)
            .count();
        assert!(grouped >= 2, "BENCH_conv.json must report ≥2 grouped/depthwise shapes");
    }
}
