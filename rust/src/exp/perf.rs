//! `sfc bench` — the conv perf-snapshot harness.
//!
//! Measures every supporting engine on a fixed set of ResNet/VGG-scale
//! layer shapes — dense plus grouped/depthwise (the MobileNet-block
//! workloads) — through the steady-state datapath (`run_into` with a
//! reused [`Workspace`]), prints a table and — with `--json` — writes a
//! machine-readable `BENCH_conv.json` so the perf trajectory of the
//! repo is tracked across PRs: per shape and engine, ns/call, GFLOP/s
//! (2·MACs / time) and the workspace heap-fallback count during the
//! timed window (0 = the zero-alloc property held). The JSON format is
//! versioned ([`BENCH_SCHEMA_VERSION`]) and documented in ENGINE.md
//! §"BENCH_conv.json schema".

use crate::engine::{default_selector, ConvDesc, QuantSpec, Workspace};
use crate::nn::Tensor;
use crate::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use crate::util::Pcg32;
use anyhow::{Context, Result};
use std::time::Instant;

/// The engines every snapshot covers (where they support the shape).
const ENGINES: [&str; 7] =
    ["direct", "im2col-gemm", "Wino(4x4,3x3)", "SFC-6(6x6,3x3)", "SFC-6(7x7,3x3)", "FFT", "NTT"];

/// One measured (shape, engine) cell.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// shape label (`-dw` = depthwise, `-gN` = grouped)
    pub shape: String,
    /// engine name (`-int8` suffix = the quantized executor)
    pub engine: String,
    /// median wall time of one call
    pub ns_per_call: f64,
    /// 2·MACs / ns_per_call (group-aware MACs)
    pub gflops: f64,
    /// the plan's reported scratch demand
    pub workspace_bytes: usize,
    /// heap fallbacks observed during the timed window (0 = zero-alloc)
    pub ws_heap_allocs_steady: u64,
}

/// Benchmark configuration (CLI flags).
pub struct BenchCfg {
    /// timed iterations per cell
    pub iters: usize,
    /// unmeasured warm-up iterations per cell
    pub warmup: usize,
    /// restrict to the smallest shape + float engines (CI smoke)
    pub quick: bool,
}

fn shapes(quick: bool) -> Vec<(&'static str, ConvDesc)> {
    let mut v = vec![
        ("28x28x32->32", ConvDesc::new(1, 32, 32, 28, 28, 3, 1, 1)),
        // depthwise 3×3 (groups == ic): the MobileNet-block workhorse
        ("28x28x32-dw", ConvDesc::new(1, 32, 32, 28, 28, 3, 1, 1).with_groups(32)),
    ];
    if !quick {
        v.push(("14x14x128->128", ConvDesc::new(1, 128, 128, 14, 14, 3, 1, 1)));
        v.push(("56x56x64->64", ConvDesc::new(1, 64, 64, 56, 56, 3, 1, 1)));
        v.push(("56x56x64-dw", ConvDesc::new(1, 64, 64, 56, 56, 3, 1, 1).with_groups(64)));
        v.push(("14x14x64-g4", ConvDesc::new(1, 64, 64, 14, 14, 3, 1, 1).with_groups(4)));
    }
    v
}

fn median_ns(samples: &mut Vec<f64>) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Run the snapshot; returns every measured row.
pub fn run_bench(cfg: &BenchCfg) -> Result<Vec<BenchRow>> {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(42);
    let mut rows = Vec::new();
    for (label, desc) in shapes(cfg.quick) {
        let mut x = Tensor::zeros(&[desc.batch, desc.ic, desc.h, desc.w]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut w = Tensor::zeros(&[desc.oc, desc.ic / desc.groups, desc.r, desc.r]);
        rng.fill_gaussian(&mut w.data, 0.2);
        let flops = 2.0 * desc.macs() as f64;
        println!("\n=== {label} ({:.1} MMACs) ===", desc.macs() as f64 / 1e6);
        for name in ENGINES {
            let Ok(plan) = sel.plan_named(name, &desc) else {
                println!("  {name:<18} (unsupported at this shape)");
                continue;
            };
            let mut ws = Workspace::new();
            let mut out = Tensor::zeros(&plan.out_dims(&x, &w));
            for _ in 0..cfg.warmup.max(1) {
                plan.run_into(&x, &w, &[], &mut ws, &mut out);
            }
            let allocs_before = ws.heap_allocs();
            let mut samples = Vec::with_capacity(cfg.iters.max(1));
            for _ in 0..cfg.iters.max(1) {
                let t0 = Instant::now();
                plan.run_into(&x, &w, &[], &mut ws, &mut out);
                std::hint::black_box(&out.data);
                samples.push(t0.elapsed().as_nanos() as f64);
            }
            let ns = median_ns(&mut samples);
            let row = BenchRow {
                shape: label.to_string(),
                engine: name.to_string(),
                ns_per_call: ns,
                gflops: flops / ns.max(1.0),
                workspace_bytes: plan.workspace_bytes(),
                ws_heap_allocs_steady: ws.heap_allocs() - allocs_before,
            };
            println!(
                "  {:<18} {:>12.0} ns/call {:>8.2} GFLOP/s  ws {:>8.1} KB  steady allocs {}",
                row.engine,
                row.ns_per_call,
                row.gflops,
                row.workspace_bytes as f64 / 1024.0,
                row.ws_heap_allocs_steady
            );
            rows.push(row);
        }
        if !cfg.quick {
            // int8 transform-domain SFC through the same reused-workspace path
            let qdesc = desc.with_quant(QuantSpec::transform_default(8));
            if let Ok(qplan) = sel.plan_named("SFC-6(7x7,3x3)", &qdesc) {
                let maxima = collect_act_maxima(&x, qplan.fast_plan().unwrap(), desc.pad);
                let q = QConvLayer::from_plan(qplan, &w, vec![], &QCalib::TransformMaxima(&maxima));
                let mut ws = Workspace::new();
                let mut out = Tensor::zeros(&q.out_dims(&x));
                for _ in 0..cfg.warmup.max(1) {
                    q.forward_into(&x, &mut ws, &mut out);
                }
                let allocs_before = ws.heap_allocs();
                let mut samples = Vec::with_capacity(cfg.iters.max(1));
                for _ in 0..cfg.iters.max(1) {
                    let t0 = Instant::now();
                    q.forward_into(&x, &mut ws, &mut out);
                    std::hint::black_box(&out.data);
                    samples.push(t0.elapsed().as_nanos() as f64);
                }
                let ns = median_ns(&mut samples);
                let row = BenchRow {
                    shape: label.to_string(),
                    engine: "SFC-6(7x7,3x3)-int8".to_string(),
                    ns_per_call: ns,
                    gflops: flops / ns.max(1.0),
                    workspace_bytes: 0,
                    ws_heap_allocs_steady: ws.heap_allocs() - allocs_before,
                };
                println!(
                    "  {:<18} {:>12.0} ns/call {:>8.2} GFLOP/s  (int8 ⊙)      steady allocs {}",
                    row.engine, row.ns_per_call, row.gflops, row.ws_heap_allocs_steady
                );
                rows.push(row);
            }
        }
    }
    Ok(rows)
}

/// The BENCH_conv.json format revision, emitted as `schema_version`.
/// Bump on any field/semantics change; the schema itself is documented
/// in ENGINE.md §"BENCH_conv.json schema".
/// v2: added `schema_version` itself + grouped/depthwise shape rows.
pub const BENCH_SCHEMA_VERSION: u32 = 2;

/// Serialize rows as the BENCH_conv.json snapshot (no serde in this
/// image — the format is flat enough to emit by hand).
pub fn to_json(rows: &[BenchRow]) -> String {
    let mut s = String::from("{\n  \"bench\": \"conv\",\n");
    s.push_str(&format!("  \"schema_version\": {BENCH_SCHEMA_VERSION},\n"));
    s.push_str(concat!(
        "  \"units\": {\"time\": \"ns/call\", \"rate\": \"GFLOP/s\"},\n",
        "  \"results\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            concat!(
                "    {{\"shape\": \"{}\", \"engine\": \"{}\", \"ns_per_call\": {:.1}, ",
                "\"gflops\": {:.4}, \"workspace_bytes\": {}, ",
                "\"ws_heap_allocs_steady\": {}}}{}\n"
            ),
            r.shape,
            r.engine,
            r.ns_per_call,
            r.gflops,
            r.workspace_bytes,
            r.ws_heap_allocs_steady,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// `sfc bench [--json] [--out PATH] [--iters N] [--warmup N] [--quick]`.
pub fn cmd_bench(cfg: &BenchCfg, json: bool, out_path: &str) -> Result<()> {
    let rows = run_bench(cfg)?;
    if json {
        let body = to_json(&rows);
        std::fs::write(out_path, &body).with_context(|| format!("write {out_path}"))?;
        println!("\nwrote {out_path} ({} rows)", rows.len());
    }
    // The headline the snapshot exists to track: GEMM-cored fast conv vs
    // the direct baseline on the 3x3 shapes.
    for (label, _) in shapes(cfg.quick) {
        let direct = rows.iter().find(|r| r.shape == label && r.engine == "direct");
        let best_fast = rows
            .iter()
            .filter(|r| {
                r.shape == label
                    && (r.engine.starts_with("SFC") || r.engine.starts_with("Wino"))
            })
            .min_by(|a, b| a.ns_per_call.partial_cmp(&b.ns_per_call).unwrap());
        if let (Some(d), Some(f)) = (direct, best_fast) {
            println!(
                "{label}: best fast engine {} at {:.2}x vs direct",
                f.engine,
                d.ns_per_call / f.ns_per_call
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_is_valid_enough() {
        let rows = vec![BenchRow {
            shape: "s".into(),
            engine: "direct".into(),
            ns_per_call: 12.5,
            gflops: 1.25,
            workspace_bytes: 64,
            ws_heap_allocs_steady: 0,
        }];
        let j = to_json(&rows);
        assert!(j.contains("\"bench\": \"conv\""));
        assert!(j.contains(&format!("\"schema_version\": {BENCH_SCHEMA_VERSION}")));
        assert!(j.contains("\"engine\": \"direct\""));
        assert!(j.contains("\"ns_per_call\": 12.5"));
        assert!(!j.contains(",\n  ]"), "no trailing comma before the array close");
    }

    #[test]
    fn quick_bench_runs_and_is_alloc_free_in_steady_state() {
        let rows = run_bench(&BenchCfg { iters: 1, warmup: 1, quick: true }).unwrap();
        assert!(rows.iter().any(|r| r.engine == "direct"));
        assert!(rows.iter().any(|r| r.engine.starts_with("SFC")));
        for r in &rows {
            assert!(r.ns_per_call > 0.0, "{}", r.engine);
            assert_eq!(r.ws_heap_allocs_steady, 0, "{} must be zero-alloc after warm-up", r.engine);
        }
        // the depthwise shape is measured, and only by engines that
        // claim grouped support (no whole-image FFT/NTT rows)
        let dw: Vec<_> = rows.iter().filter(|r| r.shape == "28x28x32-dw").collect();
        assert!(dw.iter().any(|r| r.engine == "direct"));
        assert!(dw.iter().any(|r| r.engine.starts_with("SFC") || r.engine.starts_with("Wino")));
        assert!(dw.iter().all(|r| r.engine != "FFT" && r.engine != "NTT"));
    }

    #[test]
    fn default_bench_shapes_cover_grouped_and_depthwise() {
        let grouped = shapes(false)
            .iter()
            .filter(|(_, d)| d.groups > 1)
            .count();
        assert!(grouped >= 2, "BENCH_conv.json must report ≥2 grouped/depthwise shapes");
    }
}
