//! Load generation against the multi-model scheduler: paced QPS, mixed
//! model/priority/deadline traffic, and a goodput/latency/shed report.
//!
//! This is the measurement half of the serving subsystem — the batching
//! and shedding policies in [`crate::coordinator::sched`] are only real
//! if they are drivable and observable. `sfc loadgen` builds a
//! two-model server (float + int8 by default), offers an open-loop
//! request stream at a configured rate, and reports per model: offered
//! vs. goodput, sheds by typed reason, deadline hit rate, streaming
//! p50/p99 latency, and the workspace alloc-flatness that CI soaks
//! assert on.

use crate::coordinator::sched::{MultiServer, Priority, Response, SubmitOpts, Ticket};
use crate::util::Pcg32;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Traffic shape for one [`run`].
#[derive(Clone, Copy, Debug)]
pub struct LoadgenCfg {
    /// offered request rate, summed across models (open loop)
    pub qps: f64,
    /// seconds of paced traffic
    pub duration_s: f64,
    /// deadline for low-priority requests; high-priority get 4×
    pub deadline_ms: u64,
    /// fraction of requests sent at [`Priority::Low`] (rest are High)
    pub low_ratio: f64,
    /// RNG seed for the priority mix
    pub seed: u64,
}

impl Default for LoadgenCfg {
    fn default() -> Self {
        LoadgenCfg { qps: 400.0, duration_s: 2.0, deadline_ms: 25, low_ratio: 0.6, seed: 7 }
    }
}

/// Per-model outcome of one [`run`]. Counters cover the paced phase
/// only (tallied from ticket outcomes); `p50_ms`/`p99_ms`/`batches`
/// come from the scheduler's streaming snapshot.
#[derive(Clone, Debug)]
pub struct ModelReport {
    /// model name
    pub model: String,
    /// requests offered during the paced phase
    pub offered: u64,
    /// requests completed with logits (goodput)
    pub completed: u64,
    /// requests shed, all reasons
    pub shed: u64,
    /// sheds at admission (queue full, newcomer not outranking anyone)
    pub shed_queue_full: u64,
    /// sheds by displacement (evicted for a higher-priority newcomer)
    pub shed_displaced: u64,
    /// sheds by deadline expiry while queued
    pub shed_expired: u64,
    /// requests whose batch execution failed
    pub failed: u64,
    /// completed requests that beat their deadline
    pub deadline_met: u64,
    /// streaming median completion latency, milliseconds
    pub p50_ms: f64,
    /// streaming p99 completion latency, milliseconds
    pub p99_ms: f64,
    /// batches the model's worker executed (lifetime)
    pub batches: u64,
    /// batches speculatively split by the global planner (0 under
    /// `--sched worker`)
    pub splits: u64,
    /// workspace heap fallbacks after the run (lifetime)
    pub ws_heap_allocs: u64,
    /// true when the paced phase added zero workspace heap fallbacks
    /// beyond the warm-up — the zero-steady-state-alloc contract
    pub alloc_flat: bool,
    /// queue depth after every ticket resolved (0 = clean drain)
    pub queue_final: u64,
}

/// Drive `server` at `cfg.qps` across `models` (round-robin) for
/// `cfg.duration_s`, mixing priorities and deadlines per `cfg`, and
/// return one report per model. Before pacing starts, each model gets a
/// warm-up wave (two full batches of high-priority requests) so the
/// workspace pools are populated and `alloc_flat` measures steady state
/// only.
pub fn run(server: &MultiServer, models: &[String], cfg: &LoadgenCfg) -> Result<Vec<ModelReport>> {
    anyhow::ensure!(!models.is_empty(), "loadgen needs at least one model");
    anyhow::ensure!(cfg.qps > 0.0 && cfg.duration_s > 0.0, "qps and duration must be positive");
    let mut images = Vec::with_capacity(models.len());
    for m in models {
        let len = server
            .input_len(m)
            .ok_or_else(|| anyhow::anyhow!("model '{m}' is not registered"))?;
        let mut img = vec![0f32; len];
        Pcg32::seeded(cfg.seed ^ len as u64).fill_gaussian(&mut img, 0.5);
        images.push(img);
    }

    // warm-up: fill each worker's workspace pools before measuring
    let mut warm = Vec::new();
    for (mi, m) in models.iter().enumerate() {
        for _ in 0..16 {
            warm.push(server.submit(
                m,
                images[mi].clone(),
                SubmitOpts { priority: Priority::High, deadline: Some(Duration::from_secs(60)) },
            )?);
        }
    }
    for t in warm {
        let _ = t.wait();
    }
    let warm_allocs: Vec<u64> =
        models.iter().map(|m| server.snapshot(m).map_or(0, |s| s.ws_heap_allocs)).collect();

    // paced open-loop phase
    let total = (cfg.qps * cfg.duration_s).round().max(1.0) as usize;
    let interval = Duration::from_secs_f64(1.0 / cfg.qps);
    let mut rng = Pcg32::seeded(cfg.seed);
    let mut tickets: Vec<(usize, Ticket)> = Vec::with_capacity(total);
    let mut offered = vec![0u64; models.len()];
    let start = Instant::now();
    for i in 0..total {
        let due = start + interval * i as u32;
        loop {
            let now = Instant::now();
            if now >= due {
                break;
            }
            // sleep coarsely, spin the last stretch for pacing accuracy
            let left = due - now;
            if left > Duration::from_micros(300) {
                std::thread::sleep(left - Duration::from_micros(200));
            } else {
                std::hint::spin_loop();
            }
        }
        let mi = i % models.len();
        let opts = if rng.next_f64() < cfg.low_ratio {
            SubmitOpts {
                priority: Priority::Low,
                deadline: Some(Duration::from_millis(cfg.deadline_ms)),
            }
        } else {
            SubmitOpts {
                priority: Priority::High,
                deadline: Some(Duration::from_millis(cfg.deadline_ms * 4)),
            }
        };
        offered[mi] += 1;
        tickets.push((mi, server.submit(&models[mi], images[mi].clone(), opts)?));
    }

    // collect every outcome
    let mut reports: Vec<ModelReport> = models
        .iter()
        .enumerate()
        .map(|(mi, m)| ModelReport {
            model: m.clone(),
            offered: offered[mi],
            completed: 0,
            shed: 0,
            shed_queue_full: 0,
            shed_displaced: 0,
            shed_expired: 0,
            failed: 0,
            deadline_met: 0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            batches: 0,
            splits: 0,
            ws_heap_allocs: 0,
            alloc_flat: false,
            queue_final: 0,
        })
        .collect();
    for (mi, t) in tickets {
        let rep = &mut reports[mi];
        match t.wait() {
            Ok(Response::Done(c)) => {
                rep.completed += 1;
                if c.deadline_met {
                    rep.deadline_met += 1;
                }
            }
            Ok(Response::Shed(s)) => {
                rep.shed += 1;
                match s.reason {
                    crate::coordinator::sched::ShedReason::QueueFull => rep.shed_queue_full += 1,
                    crate::coordinator::sched::ShedReason::Displaced => rep.shed_displaced += 1,
                    crate::coordinator::sched::ShedReason::DeadlineExpired => {
                        rep.shed_expired += 1
                    }
                }
            }
            Err(_) => rep.failed += 1,
        }
    }
    for (mi, rep) in reports.iter_mut().enumerate() {
        if let Some(s) = server.snapshot(&rep.model) {
            rep.p50_ms = s.latency.p50() * 1e3;
            rep.p99_ms = s.latency.p99() * 1e3;
            rep.batches = s.batches;
            rep.splits = s.splits;
            rep.ws_heap_allocs = s.ws_heap_allocs;
            rep.alloc_flat = s.ws_heap_allocs == warm_allocs[mi];
            rep.queue_final = s.queue_depth;
        }
    }
    Ok(reports)
}

/// Print the loadgen report: one grep-able `loadgen: model=...` line per
/// model (what the CI soak job asserts on) plus a closing drain line.
pub fn print_report(reports: &[ModelReport]) {
    for r in reports {
        println!(
            "loadgen: model={} offered={} goodput={} shed={} (queue_full={} displaced={} \
             expired={}) failed={} deadline_met={} p50_ms={:.2} p99_ms={:.2} batches={} \
             splits={} ws_heap_allocs={} alloc_flat={} queue_final={}",
            r.model,
            r.offered,
            r.completed,
            r.shed,
            r.shed_queue_full,
            r.shed_displaced,
            r.shed_expired,
            r.failed,
            r.deadline_met,
            r.p50_ms,
            r.p99_ms,
            r.batches,
            r.splits,
            r.ws_heap_allocs,
            r.alloc_flat,
            r.queue_final
        );
    }
    let clean = reports.iter().all(|r| r.queue_final == 0 && r.failed == 0);
    println!("loadgen: drain={}", if clean { "clean" } else { "dirty" });
}

/// Render the loadgen outcome as the `BENCH_serve.json` document
/// (schema v1), hand-rolled like the conv bench writer so the binary
/// stays dependency-free. Top level: run metadata (`bench: "serve"`,
/// kernel, threads, dispatch mode, traffic shape), executor-pool and
/// workspace-pool gauges, then one record per model. `tools/bench_gate.py`
/// gates `goodput`, `deadline_met_ratio`, and `p99_ms` per model.
pub fn report_json(
    reports: &[ModelReport],
    server: &MultiServer,
    cfg: &LoadgenCfg,
) -> String {
    let sched = server.config().dispatch;
    let pg = crate::coordinator::metrics::pool_gauges();
    let wg = server.ws_pool_gauges();
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve\",\n");
    s.push_str("  \"schema_version\": 1,\n");
    s.push_str(&format!(
        "  \"kernel\": \"{}\",\n",
        crate::coordinator::metrics::kernel_name()
    ));
    s.push_str(&format!("  \"threads\": {},\n", crate::util::par::num_threads()));
    s.push_str(&format!("  \"sched\": \"{}\",\n", sched.name()));
    s.push_str(&format!("  \"qps\": {:.1},\n", cfg.qps));
    s.push_str(&format!("  \"duration_s\": {:.2},\n", cfg.duration_s));
    s.push_str(&format!("  \"deadline_ms\": {},\n", cfg.deadline_ms));
    s.push_str(&format!("  \"low_ratio\": {:.3},\n", cfg.low_ratio));
    s.push_str(&format!("  \"seed\": {},\n", cfg.seed));
    s.push_str(&format!(
        "  \"pool\": {{\"workers\": {}, \"tasks\": {}, \"steals\": {}, \"urgent\": {}}},\n",
        pg.workers, pg.tasks, pg.steals, pg.urgent
    ));
    s.push_str(&format!(
        "  \"ws_pool\": {{\"resident_bytes\": {}, \"peak_resident_bytes\": {}, \
         \"resident_ws\": {}, \"peak_leased\": {}, \"leases\": {}, \"affinity_hits\": {}, \
         \"misses\": {}, \"dropped\": {}}},\n",
        wg.resident_bytes,
        wg.peak_resident_bytes,
        wg.resident_ws,
        wg.peak_leased,
        wg.leases,
        wg.affinity_hits,
        wg.misses,
        wg.dropped
    ));
    s.push_str("  \"models\": [\n");
    for (i, r) in reports.iter().enumerate() {
        let ratio = r.deadline_met as f64 / r.completed.max(1) as f64;
        s.push_str(&format!(
            "    {{\"model\": \"{}\", \"offered\": {}, \"goodput\": {}, \"shed\": {}, \
             \"shed_queue_full\": {}, \"shed_displaced\": {}, \"shed_expired\": {}, \
             \"failed\": {}, \"deadline_met\": {}, \"deadline_met_ratio\": {:.4}, \
             \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"batches\": {}, \"splits\": {}, \
             \"ws_heap_allocs\": {}, \"alloc_flat\": {}, \"queue_final\": {}}}{}\n",
            r.model,
            r.offered,
            r.completed,
            r.shed,
            r.shed_queue_full,
            r.shed_displaced,
            r.shed_expired,
            r.failed,
            r.deadline_met,
            ratio,
            r.p50_ms,
            r.p99_ms,
            r.batches,
            r.splits,
            r.ws_heap_allocs,
            r.alloc_flat,
            r.queue_final,
            if i + 1 < reports.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}
