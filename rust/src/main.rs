//! `sfc` — CLI for the SFC reproduction.
//!
//! Subcommands map 1:1 onto the paper's tables and figures (see
//! DESIGN.md §6) plus the build-time generators and the serving demo.
//! Hand-rolled argument parsing (clap is not vendored in this image).

use anyhow::{bail, Result};
use sfc::coordinator::parse_opt;
use std::collections::HashMap;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let opts = parse_opts(&args[1..]);
    match cmd.as_str() {
        "gen-data" => cmd_gen_data(&opts),
        "dump-algos" => cmd_dump_algos(&opts),
        "table1" => cmd_table1(&opts),
        "fig2" => cmd_fig2(),
        "table3" => cmd_table3(),
        "appendix-b" => cmd_appendix_b(),
        "table2" => sfc::exp::cmd_table2(opt(&opts, "data-dir", "artifacts"), opt(&opts, "models", "resnet18,resnet34,resnet50"), opt(&opts, "bits", "8,6")),
        "table4" => sfc::exp::cmd_table4(opt(&opts, "data-dir", "artifacts")),
        "table5" => sfc::exp::cmd_table5(opt(&opts, "data-dir", "artifacts")),
        "fig3" => sfc::exp::cmd_fig3(opt(&opts, "data-dir", "artifacts")),
        "fig4" => sfc::exp::cmd_fig4(opt(&opts, "data-dir", "artifacts")),
        "fig5" => sfc::exp::cmd_fig5(opt(&opts, "data-dir", "artifacts")),
        "serve" => sfc::coordinator::cmd_serve(&opts),
        "loadgen" => sfc::coordinator::cmd_loadgen(&opts),
        "autotune" => cmd_autotune(&opts),
        "bench" => cmd_bench(&opts),
        "graph" => cmd_graph(&opts),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other} (try `sfc help`)"),
    }
}

fn parse_opts(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                // repeated flags accumulate comma-separated, so
                // `--model a --model b` reads the same as `--model a,b`
                match map.entry(key.to_string()) {
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        let v: &mut String = e.get_mut();
                        v.push(',');
                        v.push_str(&args[i + 1]);
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(args[i + 1].clone());
                    }
                }
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn print_help() {
    println!(
        r#"sfc — SFC: Accurate Fast Convolution under Low-precision Arithmetic (ICML'24) reproduction

build-time generators:
  gen-data    [--out-dir artifacts] [--train 6000] [--test 1000] [--seed 7]
  dump-algos  [--out-dir artifacts/algos]

experiments (paper table/figure per DESIGN.md §6):
  table1      [--trials 2000] [--format fp16|int8]     numerical error / κ / complexity
  table2      [--data-dir artifacts] [--models resnet18,resnet34,resnet50] [--bits 8,6]
  table3                                               FPGA accelerator comparison
  table4      [--data-dir artifacts]                   int8 granularity ablation
  table5      [--data-dir artifacts]                   granularity × bit-width
  fig2                                                 correction-term walk-through
  fig3        [--data-dir artifacts]                   transform-domain energy
  fig4        [--data-dir artifacts]                   accuracy vs GBOPs
  fig5        [--data-dir artifacts]                   per-layer MSE under int8
  appendix-b                                           iterative large-kernel conv

engine selection (cuDNN findAlgorithm-style):
  autotune    [--model resnet18|resnet34|resnet50|mobilenet|vgg16]
              [--batch 1] [--iters 3] [--bits 0] [--out tuning.json]
              micro-benchmark every supporting engine per layer shape
              (mobilenet exercises the grouped/depthwise descriptors),
              print measured times + the selected winner (--bits N asks
              for the intN transform-domain scheme; 0 = float); also
              sweeps the GEMM Mc/Kc/Nc cache-blocking candidates on the
              largest shape's winner (pinning the fastest), the
              overlap-save tile lengths for the tiled frequency arm, and
              the compiled model end-to-end at a few batch sizes
              (per-(model, batch) exec-ns records the serving scheduler
              seeds its cost table from);
              --out writes the measured shape -> engine table
              (+ blocking + tile length + exec costs, schema v4; v1-v3
              files still load) that `serve` and `loadgen` warm from via
              --tuning (no re-measuring)

perf snapshot (steady-state pre-packed run over a reused workspace):
  bench       [--json] [--out BENCH_conv.json] [--iters 9] [--warmup 2]
              [--quick]
              per-shape, per-engine ns/call + GFLOP/s, the active kernel
              dispatch arm (avx2|neon|scalar; SFC_FORCE_SCALAR=1 pins
              scalar), the GEMM thread count (SFC_THREADS pins) and
              active Mc/Kc/Nc blocking, a scalar-vs-SIMD speedup block
              plus a 1-thread-vs-N scaling block on the dense 3x3
              shapes, end-to-end compiled-model rows (f32 + int8
              MobileNet through the graph compiler) and the executor
              pool gauges (workers/steals/spawn_avoided) — schema v7;
              --json
              writes the machine-readable snapshot tracked across PRs;
              --quick is the CI smoke subset

graph compiler (pass pipeline debuggability):
  graph       [--model resnet18|resnet34|resnet50|mobilenet] [--quant 8]
              build the model (random weights), run Model::compile()
              (conv+ReLU epilogue fusion, Add+ReLU fusion, dead-node
              elimination, int8 dataflow) and print the compiled graph:
              node, engine, fused epilogue, activation dtypes in/out and
              requantization annotations; --quant N first runs spatial
              intN PTQ on a synthetic calibration batch so the int8
              chains are visible

serving demo (L3 over PJRT artifacts, or --runner engine for the
pure-Rust workspace-backed path):
  serve       [--hlo artifacts/resnet18_b8.hlo.txt] [--data-dir artifacts]
              [--requests 256] [--batch 8] [--runner pjrt|engine]
              [--model resnet18] [--quant 8] [--tuning tuning.json]
              (--quant N: PTQ + compiled int8 dataflow, engine runner)
              multi-model: repeat --model (or comma-separate) with
              name[:intN] specs, e.g. --model resnet18 --model
              mobilenet:int8 — resident models share one plan cache and
              a packed-weight budget ([--budget-mb 0] [--queue-depth 64]
              [--linger-ms 2]); requires --runner engine; --cores N caps
              the process-wide CoreBudget (model workers x intra-op GEMM
              threads never exceed N concurrent lanes); --sched
              worker|global picks the batch dispatch planner (global =
              cost-aware EDF over all models' candidate batches, shared
              workspace pool, speculative batch splitting)

serving load generator (continuous batching under overload):
  loadgen     [--models resnet18,mobilenet:int8] [--qps 400]
              [--duration-s 2.0] [--deadline-ms 25] [--low-ratio 0.6]
              [--batch 8] [--queue-depth 32] [--budget-mb 64]
              [--linger-ms 2] [--seed 7] [--tuning tuning.json]
              [--cores N] [--sched worker|global]
              [--json] [--out BENCH_serve.json]
              open-loop paced traffic against a multi-model scheduler
              (random weights; name[:intN] specs get synthetic-calib
              PTQ): mixed priorities/deadlines, deadline-driven batch
              formation, admission control + load shedding; reports per
              model goodput, typed sheds, deadline hit rate, streaming
              p50/p99, batches, splits, workspace alloc flatness and
              drain state; --sched global routes all models through the
              cost-model-driven global planner (EDF over candidate
              batches, shared workspace pool, speculative splitting);
              --json/--out write the BENCH_serve.json snapshot
              (schema v1) that tools/bench_gate.py gates
"#
    );
}

/// `sfc graph` — print the compiled graph with fusion/requant
/// annotations (the pass-pipeline debugging view).
fn cmd_graph(opts: &HashMap<String, String>) -> Result<()> {
    use sfc::nn::model::{mobilenet_cfg, mobilenet_random, resnet_random};
    use sfc::nn::Tensor;
    use sfc::quant::{quantize_model, QuantConfig};
    use sfc::util::Pcg32;

    let model_name = opt(opts, "model", "resnet18");
    let quant_bits: u32 = parse_opt(opts, "quant", 0)?;
    let mut model = if model_name == "mobilenet" {
        mobilenet_random(&mobilenet_cfg(), 1, 10)
    } else {
        resnet_random(&resnet_cfg_by_name(model_name)?, 1, 10)
    };
    if quant_bits > 0 {
        // synthetic calibration batch: enough to exercise every scale
        let mut calib = Tensor::zeros(&[4, 3, 32, 32]);
        Pcg32::seeded(7).fill_gaussian(&mut calib.data, 1.0);
        let done = quantize_model(&mut model, &calib, &QuantConfig::direct_default(quant_bits));
        println!("PTQ: quantized {} conv layers (spatial int{quant_bits})", done.len());
    }
    let before = model.nodes.len();
    let report = model.compile();
    model.prepack_weights();
    println!(
        "compile: {} -> {} nodes · {} conv+relu fused · {} add+relu fused · {} dead removed · \
         {} int8 links",
        before,
        model.nodes.len(),
        report.conv_relu_fused,
        report.add_relu_fused,
        report.dead_removed,
        report.int8_links
    );
    print!("{}", sfc::nn::passes::describe(&model));
    Ok(())
}

fn opt<'a>(opts: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    opts.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn cmd_gen_data(opts: &HashMap<String, String>) -> Result<()> {
    let out_dir = opt(opts, "out-dir", "artifacts");
    let train_n: usize = parse_opt(opts, "train", 6000)?;
    let test_n: usize = parse_opt(opts, "test", 1000)?;
    let seed: u64 = parse_opt(opts, "seed", 7)?;
    std::fs::create_dir_all(out_dir)?;
    let train = sfc::data::synth::generate(train_n, seed);
    let test = sfc::data::synth::generate(test_n, seed + 1);
    let train_path = std::path::Path::new(out_dir).join("dataset_train.bin");
    let test_path = std::path::Path::new(out_dir).join("dataset_test.bin");
    train.save(&train_path)?;
    test.save(&test_path)?;
    println!(
        "wrote {} ({} samples) and {} ({} samples)",
        train_path.display(),
        train_n,
        test_path.display(),
        test_n
    );
    Ok(())
}

fn cmd_dump_algos(opts: &HashMap<String, String>) -> Result<()> {
    let out_dir = opt(opts, "out-dir", "artifacts/algos");
    std::fs::create_dir_all(out_dir)?;
    for spec in sfc::algo::catalog() {
        if spec.name == "direct" {
            continue;
        }
        // FFT/NTT rows have no (G, Bᵀ, Aᵀ) matrices to dump
        let Some(a) = spec.bilinear() else { continue };
        let mut s = String::new();
        s.push_str(&format!(
            "name {}\nm {}\nr {}\nt {}\nl {}\n",
            a.name,
            a.m,
            a.r,
            a.t,
            a.input_len()
        ));
        for (label, m) in [("BT", &a.bt), ("G", &a.g), ("AT", &a.at)] {
            s.push_str(&format!("{label} {} {}\n", m.rows, m.cols));
            for i in 0..m.rows {
                let row: Vec<String> = (0..m.cols)
                    .map(|j| {
                        let f = m[(i, j)];
                        if f.den == 1 {
                            format!("{}", f.num)
                        } else {
                            format!("{}/{}", f.num, f.den)
                        }
                    })
                    .collect();
                s.push_str(&row.join(" "));
                s.push('\n');
            }
        }
        let fname = spec.name.to_ascii_lowercase().replace(['(', ')', ','], "_");
        let path = std::path::Path::new(out_dir).join(format!("{fname}.txt"));
        std::fs::write(&path, s)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_table1(opts: &HashMap<String, String>) -> Result<()> {
    let trials: usize = parse_opt(opts, "trials", 2000)?;
    let fmt = match opt(opts, "format", "fp16") {
        "fp16" => sfc::error::OdotFormat::Fp16,
        "int8" => sfc::error::OdotFormat::Int(8),
        other => bail!("unknown format {other}"),
    };
    println!("Table 1 — fast convolution algorithm comparison ({trials} trials, ⊙ = {fmt:?})");
    println!("{:<20} {:>12} {:>10} {:>12}", "Algorithm", "MSE (rel)", "κ(Aᵀ)", "Complexity");
    println!("{}", "-".repeat(58));
    for row in sfc::error::table1(fmt, trials) {
        println!(
            "{:<20} {:>12.2} {:>10.1} {:>11.2}%",
            row.name,
            row.mse,
            row.kappa,
            row.complexity * 100.0
        );
    }
    println!("\npaper (Table 1): direct 1.0/1.0/100% · Wino(2,3) 2.2/2.4/44.4% · Wino(3,3) 6.4/14.5/30.4%");
    println!("  Wino(4,3) 10.5/20.1/25% · SFC-4(4,3) 2.4/2.7/31.94% · SFC-6(6,3) 2.4/3.3/27.16%");
    println!("  SFC-6(7,3) 2.6/3.4/29.93% · Wino(2,5) 10.5/20.1/36% · SFC-6(6,5) 3.6/3.5/20.44%");
    println!("  Wino(2,7) 28.1/31.0/32.6% · SFC-6(4,7) 3.6/3.5/21.99%");
    Ok(())
}

fn cmd_fig2() -> Result<()> {
    println!("Fig. 2 — converting circular outputs to linear with corrections (SFC-6(6x6,3x3), 1-D)\n");
    let a = sfc::algo::sfc(6, 6, 3);
    let t_c = 8;
    println!("circular core: {t_c} multiplications (symbolic DFT-6)");
    println!("corrections  : {} multiplications", a.t - t_c);
    for row in t_c..a.t {
        let taps: Vec<String> = (0..a.r)
            .filter(|&j| !a.g[(row, j)].is_zero())
            .map(|j| format!("w{j}"))
            .collect();
        let xs: Vec<String> = (0..a.bt.cols)
            .filter(|&j| !a.bt[(row, j)].is_zero())
            .map(|j| {
                if a.bt[(row, j)].num > 0 {
                    format!("+x{j}")
                } else {
                    format!("-x{j}")
                }
            })
            .collect();
        println!("  correction m{}: {} · ({})", row, taps.join(""), xs.join(" "));
    }
    println!("\noutputs using corrections (rows of Aᵀ):");
    for k in 0..a.m {
        let used: Vec<String> = (t_c..a.t)
            .filter(|&c| !a.at[(k, c)].is_zero())
            .map(|c| format!("m{c}"))
            .collect();
        if !used.is_empty() {
            println!("  z{k} = (inverse SFT) + {}", used.join(" + "));
        }
    }
    println!("\ntotal: {} multiplications for 6 outputs (paper: 10; 2-D: 100/88)", a.t);
    Ok(())
}

fn cmd_table3() -> Result<()> {
    use sfc::fpga::{evaluate, Accel};
    let shapes = sfc::nn::model::vgg16_conv_shapes();
    println!("Table 3 — FPGA accelerator comparison (simulated; VGG-16 conv stack @ 200 MHz)\n");
    let rows = vec![
        (
            evaluate(
                &Accel::from_bilinear("Winograd (Liang'20)", &sfc::algo::winograd(4, 3), 4, 4, 16),
                &shapes,
                "16bit",
            ),
            5.64,
        ),
        (evaluate(&Accel::ntt("NTT (Prasetiyo'23)", 8, 3, 4, 4, 21), &shapes, "8/21bit"), 3.48),
        (evaluate(&Accel::direct("direct (Huang'22)", 7, 3, 4, 4, 8), &shapes, "8bit"), 1.96),
        (
            evaluate(
                &Accel::from_bilinear("SFC (ours)", &sfc::algo::sfc(6, 7, 3), 4, 4, 8),
                &shapes,
                "8bit",
            ),
            10.08,
        ),
    ];
    println!(
        "{:<22} {:>9} {:>8} {:>7} {:>9} {:>10} {:>14} {:>9}",
        "Design", "Precision", "LUTs(K)", "DSPs", "Clock", "GOPs", "GOPs/DSP/GHz", "(paper)"
    );
    println!("{}", "-".repeat(96));
    for (r, paper) in rows {
        println!(
            "{:<22} {:>9} {:>8.0} {:>7} {:>6}MHz {:>10.0} {:>14.2} {:>9.2}",
            r.name, r.precision, r.luts_k, r.dsps, r.clock_mhz, r.gops, r.gops_per_dsp_per_clock, paper
        );
    }
    println!("\nThe headline ranking (SFC > Winograd > NTT > direct in GOPs/DSP/clock) is what");
    println!("Table 3 establishes; absolute numbers depend on place-and-route (see DESIGN.md §2).");
    Ok(())
}

fn resnet_cfg_by_name(name: &str) -> Result<sfc::nn::model::ResNetCfg> {
    use sfc::nn::model::{resnet18_cfg, resnet34_cfg, resnet50_cfg};
    Ok(match name {
        "resnet18" => resnet18_cfg(),
        "resnet34" => resnet34_cfg(),
        "resnet50" => resnet50_cfg(),
        other => bail!("unknown model {other} (try resnet18|resnet34|resnet50|mobilenet|vgg16)"),
    })
}

/// `sfc autotune` — measure every supporting engine on each distinct
/// layer shape of a model and print the per-shape winner (the cuDNN
/// `findAlgorithm` workflow over the Table-1 engine catalog).
fn cmd_autotune(opts: &HashMap<String, String>) -> Result<()> {
    use sfc::engine::{AutotuneCfg, ConvDesc, Policy, QuantSpec, Selector, TuningTable};
    use sfc::nn::model::{
        mobilenet_cfg, mobilenet_random, model_conv_descs, resnet_random, vgg16_conv_shapes,
    };

    let model_name = opt(opts, "model", "resnet18");
    let batch: usize = parse_opt(opts, "batch", 1)?;
    let iters: usize = parse_opt(opts, "iters", 3)?;
    let bits: u32 = parse_opt(opts, "bits", 0)?; // 0 = float path
    let out_path = opts.get("out").filter(|v| v.as_str() != "true");

    // Layer descriptors straight from the built model's conv plans
    // (preserving stride/pad and groups — mobilenet's dw layers are
    // depthwise); VGG-16 is a dense shape catalog without a builder.
    let descs: Vec<(String, ConvDesc)> = if model_name == "vgg16" {
        vgg16_conv_shapes()
            .into_iter()
            .enumerate()
            .map(|(i, s)| (format!("conv{}", i + 1), ConvDesc::from_shape(&s, batch)))
            .collect()
    } else if model_name == "mobilenet" {
        model_conv_descs(&mobilenet_random(&mobilenet_cfg(), 1, 10), batch)
    } else {
        let cfg = resnet_cfg_by_name(model_name)?;
        model_conv_descs(&resnet_random(&cfg, 1, 10), batch)
    };

    // Bucket layers by descriptor: repeated blocks share shapes.
    let mut buckets: Vec<(ConvDesc, Vec<String>)> = Vec::new();
    for (name, base) in &descs {
        let mut d = *base;
        if bits > 0 {
            // transform-domain scheme where fast engines apply, the
            // spatial scheme on layers only direct/NTT can quantize
            let spec = if d.r == 3 && d.stride == 1 {
                QuantSpec::transform_default(bits)
            } else {
                QuantSpec::spatial_default(bits)
            };
            d = d.with_quant(spec);
        }
        if let Some(pos) = buckets.iter().position(|(d2, _)| *d2 == d) {
            buckets[pos].1.push(name.clone());
        } else {
            buckets.push((d, vec![name.clone()]));
        }
    }

    let scheme = if bits > 0 { format!("int{bits} transform-domain") } else { "f32".to_string() };
    println!(
        "autotune — {model_name}, batch {batch}, {scheme}, {} distinct shapes from {} conv layers\n",
        buckets.len(),
        descs.len()
    );
    let sel = Selector::new(Policy::Autotune(AutotuneCfg { warmup: 1, iters }));
    let mut table = TuningTable::new();
    let mut biggest: Option<(u64, ConvDesc, String)> = None;
    for (d, names) in &buckets {
        println!(
            "shape {}x{}x{} -> {} (r={}, stride {}, pad {}, groups {}) — {} layer(s): {}",
            d.h,
            d.w,
            d.ic,
            d.oc,
            d.r,
            d.stride,
            d.pad,
            d.groups,
            names.len(),
            names.join(", ")
        );
        let entries = sel.autotune(d)?;
        println!(
            "    {:<18} {:>12} {:>12} {:>12}",
            "engine", "median", "model GBOPs", "workspace"
        );
        for t in &entries {
            println!(
                "  {} {:<18} {:>9.3} ms {:>12.4} {:>9.1} KB",
                if t.selected { "*" } else { " " },
                t.engine,
                t.median_s * 1e3,
                t.cost_bops / 1e9,
                t.workspace_bytes as f64 / 1024.0
            );
        }
        let winner = entries.iter().find(|t| t.selected).expect("autotune flags a winner");
        println!("    selected: {}\n", winner.engine);
        table.insert(d, &winner.engine, winner.median_s);
        if biggest.as_ref().map_or(true, |(m, _, _)| d.macs() > *m) {
            biggest = Some((d.macs(), *d, winner.engine.to_string()));
        }
    }

    // Cache-blocking sweep: measure the GEMM Mc/Kc/Nc candidates on the
    // largest shape's winning engine (the GEMM that dominates runtime)
    // and pin the fastest into the table, so `--tuning` warm-up installs
    // it process-wide alongside the engine pins.
    if let Some((macs, d, engine)) = biggest {
        println!("blocking sweep — {engine} on the largest shape ({:.1} MMACs):", macs as f64 / 1e6);
        let entries = sel.tune_blocking(&engine, &d, AutotuneCfg { warmup: 1, iters })?;
        for b in &entries {
            println!(
                "  {} mc={:<4} kc={:<5} nc={:<4} {:>9.3} ms",
                if b.selected { "*" } else { " " },
                b.blocking.mc,
                b.blocking.kc,
                b.blocking.nc,
                b.median_s * 1e3
            );
        }
        let win = entries.iter().find(|b| b.selected).expect("sweep flags a winner");
        table.set_blocking(Some(win.blocking));
        println!(
            "    selected blocking: mc={} kc={} nc={}\n",
            win.blocking.mc, win.blocking.kc, win.blocking.nc
        );
    }

    // Tile-length sweep: measure the overlap-save transform lengths for
    // the tiled frequency-domain arm on the largest shape it supports
    // and pin the fastest (schema v3), so `--tuning` warm-up installs it
    // process-wide alongside the blocking.
    let tiled_engine = if bits > 0 { "NTT-tiled" } else { "FFT-tiled" };
    if let Some((macs, d)) = buckets
        .iter()
        .filter(|(d, _)| sel.engine_named(tiled_engine).is_some_and(|e| e.supports(d)))
        .map(|(d, _)| (d.macs(), *d))
        .max_by_key(|(m, _)| *m)
    {
        println!(
            "tile sweep — {tiled_engine} on the largest supported shape ({:.1} MMACs):",
            macs as f64 / 1e6
        );
        let entries = sel.tune_tile_len(tiled_engine, &d, AutotuneCfg { warmup: 1, iters })?;
        for t in &entries {
            println!(
                "  {} tile={:<4} {:>9.3} ms",
                if t.selected { "*" } else { " " },
                t.tile_len,
                t.median_s * 1e3
            );
        }
        let win = entries.iter().find(|t| t.selected).expect("sweep flags a winner");
        table.set_tile_len(Some(win.tile_len));
        println!("    selected tile length: {}\n", win.tile_len);
    }

    // Exec-cost sweep (schema v4): run the compiled model end to end at
    // a few batch sizes and record the median ns/batch, so the serving
    // scheduler seeds its per-(model, batch-size) cost table — the
    // worker arm's EWMA cold start and the global planner's predictions
    // — from measurements instead of the 500 µs default.
    if model_name != "vgg16" {
        let mut exec_batches = vec![1usize, 8];
        if !exec_batches.contains(&batch) {
            exec_batches.push(batch);
        }
        exec_batches.sort_unstable();
        println!("exec sweep — {model_name} end-to-end ns/batch (schema v4 exec records):");
        for &n in &exec_batches {
            let m = if model_name == "mobilenet" {
                mobilenet_random(&mobilenet_cfg(), 1, 10)
            } else {
                resnet_random(&resnet_cfg_by_name(model_name)?, 1, 10)
            };
            let exe = sfc::runtime::EngineExecutor::from_model(m, vec![n, 3, 32, 32], 10);
            let mut ws = sfc::engine::Workspace::new();
            let input = vec![0.1f32; n * 3 * 32 * 32];
            let mut out = Vec::new();
            exe.run_with_into(&input, &mut ws, &mut out)?; // warm the arenas
            let mut samples = Vec::with_capacity(iters.max(1));
            for _ in 0..iters.max(1) {
                let t0 = std::time::Instant::now();
                exe.run_with_into(&input, &mut ws, &mut out)?;
                samples.push(t0.elapsed().as_nanos() as f64);
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = samples[samples.len() / 2];
            table.set_exec_ns(model_name, n, med);
            println!("  batch {n:<3} {:>9.3} ms/batch", med / 1e6);
        }
        println!();
    }

    if let Some(path) = out_path {
        table.save(std::path::Path::new(path))?;
        println!(
            "wrote {} ({} measured shape -> engine pins; warm `sfc serve`/`sfc loadgen` \
             with --tuning {})",
            path,
            table.len(),
            path
        );
    }

    // Repeated model construction reuses cached plans — the serving-path
    // property the PlanCache exists for.
    if model_name != "vgg16" {
        let (h0, _) = sfc::coordinator::metrics::plan_cache_counters();
        if model_name == "mobilenet" {
            let _ = mobilenet_random(&mobilenet_cfg(), 2, 10);
        } else {
            let cfg = resnet_cfg_by_name(model_name)?;
            let _ = resnet_random(&cfg, 2, 10);
        }
        let (h1, m1) = sfc::coordinator::metrics::plan_cache_counters();
        println!(
            "rebuilt {model_name}: +{} plan-cache hits from shared layer shapes",
            h1 - h0
        );
        println!("plan cache totals: {h1} hits / {m1} misses (process-wide)");
    } else {
        let (h, m) = sfc::coordinator::metrics::plan_cache_counters();
        println!("plan cache totals: {h} hits / {m} misses (process-wide)");
    }
    Ok(())
}

/// `sfc bench` — the perf snapshot harness (see `exp::perf`).
fn cmd_bench(opts: &HashMap<String, String>) -> Result<()> {
    let cfg = sfc::exp::perf::BenchCfg {
        iters: parse_opt(opts, "iters", 9)?,
        warmup: parse_opt(opts, "warmup", 2)?,
        quick: opts.get("quick").is_some(),
    };
    let json = opts.get("json").is_some();
    let out = opt(opts, "out", "BENCH_conv.json");
    sfc::exp::perf::cmd_bench(&cfg, json, out)
}

fn cmd_appendix_b() -> Result<()> {
    use sfc::algo::iterative;
    println!("Appendix B — iterative SFC for large kernels\n");
    let c = iterative::paper_example_cost();
    println!("29×29 kernel on a 26×26 map:");
    println!("  direct convolution      : {:>9} multiplications", c.direct_mults);
    println!("  iteration 1 (tiled SFC) : {:>9} multiplications", c.one_iter_mults);
    println!(
        "  iteration 2 (SFC ∘ SFC) : {:>9} multiplications  ({:.1}% of direct; paper quotes 17,424 = 3.1%)",
        c.two_iter_mults,
        100.0 * c.two_iter_mults as f64 / c.direct_mults as f64
    );
    use sfc::linalg::Mat;
    use sfc::util::Pcg32;
    let mut rng = Pcg32::seeded(99);
    let x = Mat::from_vec(40, 40, (0..1600).map(|_| rng.next_gaussian()).collect());
    let k = Mat::from_vec(29, 29, (0..841).map(|_| rng.next_gaussian()).collect());
    let algo = sfc::algo::sfc(6, 6, 5);
    let got = iterative::iterative_conv2d(&x, &k, &algo);
    let want = sfc::algo::direct_conv2d(&x, &k);
    let mse: f64 = got.data.iter().zip(&want.data).map(|(a, b)| (a - b).powi(2)).sum::<f64>()
        / got.data.len() as f64;
    println!("\nfunctional check vs naive 29×29 conv: MSE = {mse:.2e} (float roundoff only)");
    Ok(())
}
