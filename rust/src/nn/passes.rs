//! The graph compiler: an explicit optimization pass pipeline over
//! [`Model`].
//!
//! [`compile`] (reached via [`Model::compile`]) lowers the straight-line
//! SSA graph the topology builders emit into the form the serving stack
//! executes:
//!
//! 1. **Epilogue fusion** — a `Relu` whose producer is a single-consumer
//!    `Conv` is folded into the conv's plan as
//!    [`Epilogue::Relu`](crate::engine::Epilogue) (applied inside the
//!    executor's scatter/output loop, and part of the plan-cache key);
//!    a `Relu` over a single-consumer `Add` becomes the fused
//!    [`Op::AddRelu`] residual join. Either way the separate full-tensor
//!    activation pass disappears.
//! 2. **Dead-node elimination** — nodes unreachable from the model
//!    output (including the fused-away `Relu`s) are dropped and inputs
//!    remapped; the output node stays last, so `Model` execution
//!    semantics are unchanged.
//! 3. **Int8 dataflow** — for every spatially-quantized conv whose
//!    consumers are all spatially-quantized convs sharing one calibrated
//!    input quantizer, an integer requantization output stage
//!    ([`crate::quant::QConvLayer::install_requant`]) is installed: the producer emits
//!    int8 codes directly on the consumer's grid (per-channel
//!    fixed-point `(m0, shift)` multipliers, fused ReLU as a clamp floor
//!    at 0), eliminating the dequantize→f32→quantize hop on every such
//!    edge.
//!
//! The pipeline is idempotent: compiling a compiled model finds nothing
//! left to fuse. PTQ composes in either order — `quantize_model`
//! preserves fused epilogues, and re-running [`compile`] after PTQ
//! installs the int8 dataflow over the fresh quantized layers.

use super::graph::{Model, Op};
use crate::engine::{default_selector, Epilogue};
use crate::quant::QParams;

/// What one [`compile`] run changed — printed by `sfc graph` and
/// asserted by the graph-compiler tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompileReport {
    /// `Conv → Relu` pairs fused into a conv epilogue
    pub conv_relu_fused: usize,
    /// `Add → Relu` pairs fused into [`Op::AddRelu`]
    pub add_relu_fused: usize,
    /// nodes removed as unreachable from the output (fused-away `Relu`
    /// nodes are not counted here)
    pub dead_removed: usize,
    /// producer→consumer edges converted to direct int8 dataflow
    /// (requant stages installed on the producers)
    pub int8_links: usize,
}

/// Run the pass pipeline over `model` in place. See the module docs for
/// the pass list; returns what changed.
pub fn compile(model: &mut Model) -> CompileReport {
    let (conv_relu_fused, add_relu_fused, dead_removed) = fuse_and_prune(model);
    let int8_links = int8_dataflow(model);
    CompileReport { conv_relu_fused, add_relu_fused, dead_removed, int8_links }
}

/// How many nodes consume each node's output.
fn consumer_counts(model: &Model) -> Vec<usize> {
    let mut c = vec![0usize; model.nodes.len()];
    for n in &model.nodes {
        for &i in &n.inputs {
            c[i] += 1;
        }
    }
    c
}

/// Epilogue fusion + dead-node elimination in one rebuild, preserving
/// the output-is-last-node invariant (every node reachable from the
/// output has a smaller index, so pruning to the reachable set keeps
/// the output last).
fn fuse_and_prune(model: &mut Model) -> (usize, usize, usize) {
    let n = model.nodes.len();
    if n == 0 {
        return (0, 0, 0);
    }
    let consumers = consumer_counts(model);
    // remap[i]: the node whose output now stands for i's (identity
    // unless i is a fused-away Relu); dropped[i]: i leaves the graph.
    let mut remap: Vec<usize> = (0..n).collect();
    let mut dropped = vec![false; n];
    // fusion sites, counted only if the fused node survives DCE (a
    // fusion inside a dead subgraph is not a fusion of the compiled
    // graph)
    let mut conv_fused_at = vec![false; n];
    let mut add_fused_at = vec![false; n];
    for i in 0..n {
        if !matches!(model.nodes[i].op, Op::Relu) {
            continue;
        }
        let src = model.nodes[i].inputs[0];
        // the pre-activation value must have no other consumer
        if consumers[src] != 1 || dropped[src] {
            continue;
        }
        let src_op = &mut model.nodes[src].op;
        match src_op {
            Op::Conv { plan, packed, quantized, .. } => {
                if plan.desc.epilogue != Epilogue::None {
                    continue; // already fused (idempotence)
                }
                let desc = plan.desc.with_epilogue(Epilogue::Relu);
                // same engine, epilogue-annotated descriptor; the plan
                // cache keys on (desc, engine) so fused plans are shared
                let Ok(newplan) = default_selector().plan_named(plan.engine, &desc) else {
                    continue;
                };
                // a PTQ'd node carries its own plan (different engine +
                // quant descriptor than the float plan) — refit it
                // against its own engine, and only fuse when that works
                if let Some(q) = quantized {
                    let qdesc = q.plan.desc.with_epilogue(Epilogue::Relu);
                    let Ok(qplan) = default_selector().plan_named(q.plan.engine, &qdesc) else {
                        continue;
                    };
                    q.plan = qplan;
                }
                *plan = newplan;
                // pre-packed weights carry the descriptor — drop the
                // stale artifact; Model::prepack_weights re-packs
                *packed = None;
                remap[i] = src;
                dropped[i] = true;
                conv_fused_at[src] = true;
            }
            Op::Add => {
                *src_op = Op::AddRelu;
                remap[i] = src;
                dropped[i] = true;
                add_fused_at[src] = true;
            }
            _ => {}
        }
    }
    // Reachability from the (possibly remapped) output node.
    let resolve = |mut i: usize| -> usize {
        while dropped[i] {
            debug_assert_ne!(remap[i], i, "dropped node without a replacement");
            i = remap[i];
        }
        i
    };
    let out = resolve(n - 1);
    let mut live = vec![false; n];
    let mut stack = vec![out];
    while let Some(i) = stack.pop() {
        if live[i] {
            continue;
        }
        live[i] = true;
        for &inp in &model.nodes[i].inputs {
            stack.push(resolve(inp));
        }
    }
    let dead_removed = (0..n).filter(|&i| !dropped[i] && !live[i]).count();
    let conv_fused = (0..n).filter(|&i| conv_fused_at[i] && live[i]).count();
    let add_fused = (0..n).filter(|&i| add_fused_at[i] && live[i]).count();
    // Rebuild: keep live nodes in order, remap inputs through the fused
    // Relus to the new dense indices.
    let mut new_idx = vec![usize::MAX; n];
    let mut k = 0usize;
    for i in 0..n {
        if live[i] {
            new_idx[i] = k;
            k += 1;
        }
    }
    let nodes = std::mem::take(&mut model.nodes);
    model.nodes = nodes
        .into_iter()
        .enumerate()
        .filter(|(i, _)| live[*i])
        .map(|(_, mut node)| {
            for inp in node.inputs.iter_mut() {
                *inp = new_idx[resolve(*inp)];
            }
            node
        })
        .collect();
    (conv_fused, add_fused, dead_removed)
}

/// Install integer requantization on every spatially-quantized conv
/// whose consumers are all spatially-quantized convs with one common
/// calibrated input quantizer. Returns the number of producer→consumer
/// edges that now carry int8 activations.
fn int8_dataflow(model: &mut Model) -> usize {
    let n = model.nodes.len();
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in model.nodes.iter().enumerate() {
        for &inp in &node.inputs {
            consumers[inp].push(i);
        }
    }
    // a consumer's calibrated input quantizer, when it is a
    // spatially-quantized conv (the only ops that can take int8 input)
    let in_qparams = |op: &Op| -> Option<QParams> {
        match op {
            Op::Conv { quantized: Some(q), .. } => q.spatial_in_qparams(),
            _ => None,
        }
    };
    let mut links = 0usize;
    for p in 0..n {
        // the producer must itself be a spatially-quantized conv
        if in_qparams(&model.nodes[p].op).is_none() || consumers[p].is_empty() {
            continue;
        }
        let mut out_qp: Option<QParams> = None;
        let mut ok = true;
        for &c in &consumers[p] {
            match (in_qparams(&model.nodes[c].op), out_qp) {
                (Some(qp), None) => out_qp = Some(qp),
                (Some(qp), Some(prev))
                    if qp.scale.to_bits() == prev.scale.to_bits() && qp.qmax == prev.qmax => {}
                _ => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        let Some(out_qp) = out_qp else { continue };
        if let Op::Conv { quantized: Some(q), .. } = &mut model.nodes[p].op {
            // idempotence: a stage installed by an earlier compile with
            // the same output quantizer is left alone and not re-counted
            let already = q.out_qparams().is_some_and(|cur| {
                cur.scale.to_bits() == out_qp.scale.to_bits() && cur.qmax == out_qp.qmax
            });
            if !already && q.install_requant(out_qp) {
                links += consumers[p].len();
            }
        }
    }
    links
}

/// Render the compiled graph as the `sfc graph` debug table: one row
/// per node with op kind, executing engine, fused epilogue, activation
/// dtypes in/out and the int8-dataflow annotation.
pub fn describe(model: &Model) -> String {
    use std::fmt::Write;
    // which nodes produce int8 activations
    let emits_i8: Vec<bool> = model
        .nodes
        .iter()
        .map(|n| matches!(&n.op, Op::Conv { quantized: Some(q), .. } if q.produces_q()))
        .collect();
    let dtype = |i: usize| if emits_i8[i] { "int8" } else { "f32" };
    let mut s = String::new();
    let _ = writeln!(s, "graph {} ({} nodes)", model.name, model.nodes.len());
    let _ = writeln!(
        s,
        "{:>3}  {:<18} {:<9} {:<22} {:<5} {:<11} {}",
        "#", "name", "op", "engine", "epi", "dtype", "notes"
    );
    for (i, node) in model.nodes.iter().enumerate() {
        let ins = if node.inputs.is_empty() {
            "-".to_string()
        } else {
            node.inputs.iter().map(|j| dtype(*j)).collect::<Vec<_>>().join("+")
        };
        let io = format!("{}->{}", ins, dtype(i));
        let (kind, engine, epi, note) = match &node.op {
            Op::Input => ("input", String::from("-"), "-", String::new()),
            Op::Conv { plan, packed, quantized, .. } => {
                let epi = plan.desc.epilogue.name();
                match quantized {
                    Some(q) => {
                        let note = match q.out_qparams() {
                            Some(qp) => format!(
                                "requant per-channel (m0,shift) -> s_out {:.4e}",
                                qp.scale
                            ),
                            None => "dequant f32 out".to_string(),
                        };
                        ("conv", format!("{}-int8", q.engine()), epi, note)
                    }
                    None => {
                        let note =
                            if packed.is_some() { "pre-packed".to_string() } else { String::new() };
                        ("conv", plan.engine.to_string(), epi, note)
                    }
                }
            }
            Op::Relu => ("relu", String::from("-"), "-", String::new()),
            Op::MaxPool2 => ("maxpool2", String::from("-"), "-", String::new()),
            Op::GlobalAvgPool => ("gap", String::from("-"), "-", String::new()),
            Op::Linear { .. } => ("linear", String::from("-"), "-", String::new()),
            Op::Add => ("add", String::from("-"), "-", String::new()),
            Op::AddRelu => ("add", String::from("-"), "relu", "fused residual join".to_string()),
        };
        let _ = writeln!(
            s,
            "{i:>3}  {:<18} {:<9} {:<22} {:<5} {:<11} {}",
            node.name, kind, engine, epi, io, note
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ConvDesc, ConvPlan};
    use crate::nn::graph::ConvParams;
    use crate::nn::tensor::Tensor;
    use crate::util::Pcg32;
    use std::sync::Arc;

    fn conv_node(m: &mut Model, input: usize, rng: &mut Pcg32, name: &str) -> usize {
        let mut w = Tensor::zeros(&[4, 4, 3, 3]);
        rng.fill_gaussian(&mut w.data, 0.3);
        let desc = ConvDesc::new(1, 4, 4, 8, 8, 3, 1, 1);
        m.push(
            Op::Conv {
                params: ConvParams { weight: w, bias: vec![0.1; 4], stride: 1, pad: 1 },
                plan: Arc::new(ConvPlan::direct(desc)),
                packed: None,
                quantized: None,
            },
            vec![input],
            name,
        )
    }

    #[test]
    fn relu_fuses_into_single_consumer_conv() {
        let mut rng = Pcg32::seeded(1);
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let c = conv_node(&mut m, i, &mut rng, "conv");
        m.push(Op::Relu, vec![c], "relu");
        let mut x = Tensor::zeros(&[1, 4, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let want = m.forward(&x);
        let report = m.compile();
        assert_eq!(report.conv_relu_fused, 1);
        assert_eq!(m.nodes.len(), 2, "the relu node is gone");
        let Op::Conv { plan, .. } = &m.nodes[1].op else { panic!("conv survives") };
        assert_eq!(plan.desc.epilogue, Epilogue::Relu);
        assert_eq!(m.forward(&x).data, want.data, "fusion is bit-identical");
        // idempotent
        let report2 = m.compile();
        assert_eq!(report2, CompileReport::default());
    }

    #[test]
    fn relu_with_shared_preactivation_is_not_fused() {
        // conv's output is consumed by the relu AND a residual add —
        // fusing would corrupt the second consumer's value
        let mut rng = Pcg32::seeded(2);
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let c = conv_node(&mut m, i, &mut rng, "conv");
        let r = m.push(Op::Relu, vec![c], "relu");
        m.push(Op::Add, vec![c, r], "add");
        let mut x = Tensor::zeros(&[1, 4, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let want = m.forward(&x);
        let report = m.compile();
        assert_eq!(report.conv_relu_fused, 0);
        assert_eq!(m.forward(&x).data, want.data);
    }

    #[test]
    fn add_relu_fuses_and_dead_nodes_are_pruned() {
        let mut rng = Pcg32::seeded(3);
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let c1 = conv_node(&mut m, i, &mut rng, "conv1");
        let c2 = conv_node(&mut m, i, &mut rng, "conv2");
        // dangling auxiliary head: unreachable from the output
        conv_node(&mut m, c1, &mut rng, "aux");
        let add = m.push(Op::Add, vec![c1, c2], "add");
        m.push(Op::Relu, vec![add], "relu");
        let mut x = Tensor::zeros(&[1, 4, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let want = m.forward(&x);
        let report = m.compile();
        assert_eq!(report.add_relu_fused, 1);
        assert_eq!(report.dead_removed, 1, "the aux head is unreachable");
        assert!(matches!(m.nodes.last().unwrap().op, Op::AddRelu));
        assert_eq!(m.forward(&x).data, want.data, "AddRelu is bit-identical to add→relu");
    }

    #[test]
    fn describe_annotates_fusion() {
        let mut rng = Pcg32::seeded(4);
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let c = conv_node(&mut m, i, &mut rng, "convX");
        m.push(Op::Relu, vec![c], "relu");
        m.compile();
        let s = describe(&m);
        assert!(s.contains("convX"), "{s}");
        assert!(s.contains("relu"), "fused epilogue shown: {s}");
        assert!(s.contains("f32->f32"), "{s}");
    }
}
