//! Tiny SSA graph IR for CNN inference.
//!
//! Each node consumes earlier node outputs by index; this is enough for
//! the ResNet family (residual adds) and VGG (pure chains) while keeping
//! forward execution trivially auditable for the PTQ experiments.
//!
//! Two execution modes share one set of per-op kernels:
//! [`Model::forward_all`] keeps every activation (calibration, probes),
//! while [`Model::forward_ws`] / [`Model::forward_ws_owned`] run out of a
//! caller [`Workspace`], recycling each activation the moment its last
//! consumer ran — the zero-alloc serving path.

use super::tensor::Tensor;
use crate::engine::{packed_bytes_estimate, ConvPlan, PackBudget, PackedWeights, Workspace};
use crate::quant::qconv::QConvLayer;
use crate::quant::QTensor;
use std::sync::Arc;

/// One conv layer's parameters (BN already folded at export time).
#[derive(Clone, Debug)]
pub struct ConvParams {
    /// `[OC, IC/groups, R, R]` filter bank (the weight shape is what
    /// encodes the channel grouping)
    pub weight: Tensor,
    /// per-output-channel bias (may be empty)
    pub bias: Vec<f32>,
    /// spatial stride
    pub stride: usize,
    /// symmetric zero padding
    pub pad: usize,
}

/// Outcome of [`Model::prepack_weights_budgeted`]: how many conv layers
/// were pre-packed vs. skipped by the budget, and the bytes added.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrepackReport {
    /// float conv layers whose weights were pre-transformed + packed
    pub packed_layers: usize,
    /// layers skipped by the budget (they run the per-call path)
    pub skipped_layers: usize,
    /// packed bytes added by this call
    pub added_bytes: usize,
}

/// One graph operation.
pub enum Op {
    /// Graph input placeholder.
    Input,
    /// Convolution through an engine plan (float or quantized).
    Conv {
        /// weights/bias and geometry
        params: ConvParams,
        /// engine-selected execution plan (see [`crate::engine`])
        plan: Arc<ConvPlan>,
        /// plan-time pre-packed weights ([`Model::prepack_weights`]);
        /// when set, the workspace forward runs
        /// [`ConvPlan::run_packed_into`] — bit-identical to the
        /// per-call path, minus the per-call transform + packing
        packed: Option<Arc<PackedWeights>>,
        /// set by the PTQ pass: quantized executor overriding `plan`
        quantized: Option<QConvLayer>,
    },
    /// Element-wise max(0, x).
    Relu,
    /// 2×2 max-pool, stride 2.
    MaxPool2,
    /// Spatial mean per channel → [N, C, 1, 1].
    GlobalAvgPool,
    /// Fully-connected head.
    Linear {
        /// OUT×IN weight matrix
        weight: Tensor,
        /// per-output bias
        bias: Vec<f32>,
    },
    /// Element-wise sum of the two inputs (residual join).
    Add,
    /// Fused residual join: `max(0, a + b)` in one pass (produced by the
    /// graph compiler's Add+ReLU fusion, bit-identical to `Add → Relu`).
    AddRelu,
}

/// One node's activation value: a float tensor, or the int8 codes a
/// requantizing conv produced for a downstream quantized conv (the
/// compiled int8 dataflow — see [`crate::nn::passes`]).
pub enum Act {
    /// f32 activation
    F32(Tensor),
    /// int8 activation (codes + scale)
    I8(QTensor),
}

impl Act {
    /// Dimension sizes (NCHW for conv activations).
    pub fn dims(&self) -> &[usize] {
        match self {
            Act::F32(t) => &t.dims,
            Act::I8(q) => &q.dims,
        }
    }

    /// The f32 tensor, panicking with context if the activation is
    /// int8 (ops other than quantized convs require float inputs; the
    /// compiler only routes int8 into quantized convs).
    fn expect_f32(&self, name: &str) -> &Tensor {
        match self {
            Act::F32(t) => t,
            Act::I8(_) => panic!("{name}: op requires an f32 input but got an int8 activation"),
        }
    }
}

/// One SSA node: an op applied to earlier nodes' outputs.
pub struct Node {
    /// the operation
    pub op: Op,
    /// indices of the consumed nodes
    pub inputs: Vec<usize>,
    /// diagnostic name (weight-map prefix)
    pub name: String,
}

/// A CNN inference graph in SSA form.
pub struct Model {
    /// nodes in topological order
    pub nodes: Vec<Node>,
    /// model name
    pub name: String,
}

// --- per-op kernels, shared by forward_all and the workspace path ---

fn relu_inplace(t: &mut Tensor) {
    for v in t.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

fn maxpool2_dims(inp: &Tensor) -> Vec<usize> {
    let (n, c, h, w) = inp.dims4();
    vec![n, c, h / 2, w / 2]
}

fn maxpool2_into(inp: &Tensor, out: &mut Tensor) {
    let (n, c, h, w) = inp.dims4();
    let (oh, ow) = (h / 2, w / 2);
    out.assert_dims(&[n, c, oh, ow]);
    for ni in 0..n {
        for ci in 0..c {
            let src = inp.plane(ni, ci);
            let dst = out.plane_mut(ni, ci);
            for y in 0..oh {
                for x in 0..ow {
                    let m = src[2 * y * w + 2 * x]
                        .max(src[2 * y * w + 2 * x + 1])
                        .max(src[(2 * y + 1) * w + 2 * x])
                        .max(src[(2 * y + 1) * w + 2 * x + 1]);
                    dst[y * ow + x] = m;
                }
            }
        }
    }
}

fn gap_dims(inp: &Tensor) -> Vec<usize> {
    let (n, c, _, _) = inp.dims4();
    vec![n, c, 1, 1]
}

fn global_avg_pool_into(inp: &Tensor, out: &mut Tensor) {
    let (n, c, h, w) = inp.dims4();
    out.assert_dims(&[n, c, 1, 1]);
    for ni in 0..n {
        for ci in 0..c {
            let s: f32 = inp.plane(ni, ci).iter().sum();
            *out.at4_mut(ni, ci, 0, 0) = s / (h * w) as f32;
        }
    }
}

fn linear_dims(inp: &Tensor, weight: &Tensor) -> Vec<usize> {
    vec![inp.dims[0], weight.dims[0], 1, 1]
}

fn linear_into(inp: &Tensor, weight: &Tensor, bias: &[f32], out: &mut Tensor) {
    let n = inp.dims[0];
    let in_dim: usize = inp.dims[1..].iter().product();
    let out_dim = weight.dims[0];
    assert_eq!(weight.dims[1], in_dim);
    out.assert_dims(&[n, out_dim, 1, 1]);
    for ni in 0..n {
        let xrow = &inp.data[ni * in_dim..(ni + 1) * in_dim];
        for o in 0..out_dim {
            let wrow = &weight.data[o * in_dim..(o + 1) * in_dim];
            let mut acc = if bias.is_empty() { 0.0 } else { bias[o] };
            for (a, b) in xrow.iter().zip(wrow) {
                acc += a * b;
            }
            *out.at4_mut(ni, o, 0, 0) = acc;
        }
    }
}

fn add_assign(t: &mut Tensor, b: &Tensor, name: &str) {
    assert_eq!(t.dims, b.dims, "residual shape mismatch at {name}");
    for (x, y) in t.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// The fused residual join: one pass computing `max(0, a + b)` —
/// bit-identical to [`add_assign`] followed by [`relu_inplace`] (same
/// `v < 0.0` comparison).
fn add_relu_assign(t: &mut Tensor, b: &Tensor, name: &str) {
    assert_eq!(t.dims, b.dims, "residual shape mismatch at {name}");
    for (x, y) in t.data.iter_mut().zip(&b.data) {
        let v = *x + y;
        *x = if v < 0.0 { 0.0 } else { v };
    }
}

/// A tensor whose buffer is checked out of the workspace (zeroed).
fn ws_tensor(ws: &mut Workspace, dims: &[usize]) -> Tensor {
    Tensor::from_vec(dims, ws.take_f32(dims.iter().product()))
}

/// An int8 activation whose buffer is checked out of the workspace;
/// the executor sets the scale from its requant stage.
fn ws_qtensor(ws: &mut Workspace, dims: &[usize]) -> QTensor {
    QTensor { data: ws.take_i8(dims.iter().product()), dims: dims.to_vec(), scale: 0.0 }
}

/// Return an activation's buffer to the workspace pool.
fn give_act(ws: &mut Workspace, a: Act) {
    match a {
        Act::F32(t) => ws.give_f32(t.data),
        Act::I8(q) => ws.give_i8(q.data),
    }
}

impl Model {
    /// An empty graph.
    pub fn new(name: &str) -> Model {
        Model { nodes: Vec::new(), name: name.into() }
    }

    /// Append a node; returns its index.
    pub fn push(&mut self, op: Op, inputs: Vec<usize>, name: impl Into<String>) -> usize {
        self.nodes.push(Node { op, inputs, name: name.into() });
        self.nodes.len() - 1
    }

    /// Indices of all conv nodes (the layers PTQ operates on).
    pub fn conv_nodes(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, Op::Conv { .. }))
            .map(|(i, _)| i)
            .collect()
    }

    /// Run the graph compiler's pass pipeline over the model in place —
    /// conv+ReLU epilogue fusion, Add+ReLU fusion into [`Op::AddRelu`],
    /// dead-node elimination, and the int8-dataflow pass that installs
    /// integer requantization between consecutive spatially-quantized
    /// convs (see [`crate::nn::passes`]). Idempotent; bit-identical for
    /// float graphs, and the serving entry point
    /// (`EngineExecutor::from_model`) runs it before pre-packing
    /// weights. Returns the pass report.
    pub fn compile(&mut self) -> crate::nn::passes::CompileReport {
        crate::nn::passes::compile(self)
    }

    /// Pre-transform + pre-pack every float conv layer's weights once
    /// (plan time), so steady-state [`Model::forward_ws`] runs
    /// [`ConvPlan::run_packed_into`] over pre-packed operands only.
    /// Idempotent; layers the PTQ pass quantized keep their own packed
    /// panels inside the [`QConvLayer`]. Returns the packed bytes added.
    pub fn prepack_weights(&mut self) -> usize {
        self.prepack_weights_budgeted(&PackBudget::unlimited()).added_bytes
    }

    /// Like [`Model::prepack_weights`] but under a [`PackBudget`]: each
    /// layer's packed size is estimated ([`packed_bytes_estimate`],
    /// exact by construction) and the layer is only pre-packed if it
    /// fits next to everything already packed process-wide. Skipped
    /// layers degrade gracefully — [`Model::forward_ws`] falls back to
    /// the per-call transform+pack path for them, bit-identical, just
    /// without the plan-time speedup.
    pub fn prepack_weights_budgeted(&mut self, budget: &PackBudget) -> PrepackReport {
        let mut report = PrepackReport::default();
        for node in &mut self.nodes {
            if let Op::Conv { params, plan, packed, quantized } = &mut node.op {
                if quantized.is_none() && packed.is_none() {
                    let est = packed_bytes_estimate(plan);
                    if budget.try_admit(est) {
                        let p = Arc::new(PackedWeights::pack(plan, &params.weight));
                        report.added_bytes += p.bytes();
                        report.packed_layers += 1;
                        *packed = Some(p);
                    } else {
                        report.skipped_layers += 1;
                    }
                }
            }
        }
        report
    }

    /// Forward pass; returns every node's activation (used by PTQ
    /// calibration and the Fig.-3/Fig.-5 per-layer probes). On a
    /// compiled graph the execution follows the compiled dataflow
    /// (fused epilogues, int8 links); int8 activations are dequantized
    /// for the returned probe list only — the edges between quantized
    /// convs stay integer.
    pub fn forward_all(&self, x: &Tensor) -> Vec<Tensor> {
        let mut ws = Workspace::new();
        let mut acts: Vec<Act> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let out = match &node.op {
                Op::Input => Act::F32(x.clone()),
                Op::Conv { params, plan, quantized, .. } => {
                    debug_assert_eq!(
                        (params.stride, params.pad),
                        (plan.desc.stride, plan.desc.pad),
                        "ConvParams and plan descriptor disagree at {}",
                        node.name
                    );
                    debug_assert_eq!(
                        params.weight.dims[1] * plan.desc.groups,
                        plan.desc.ic,
                        "weight grouping and plan descriptor disagree at {}",
                        node.name
                    );
                    let inp = &acts[node.inputs[0]];
                    match quantized {
                        Some(q) => {
                            let odims = q.out_dims_for(inp.dims());
                            if q.produces_q() {
                                let mut qt = QTensor {
                                    data: vec![0i8; odims.iter().product()],
                                    dims: odims,
                                    scale: 0.0,
                                };
                                match inp {
                                    Act::F32(t) => q.forward_into_q(t, &mut ws, &mut qt),
                                    Act::I8(t) => q.forward_q_into_q(t, &mut ws, &mut qt),
                                }
                                Act::I8(qt)
                            } else {
                                let mut t = Tensor::zeros(&odims);
                                match inp {
                                    Act::F32(xt) => q.forward_into(xt, &mut ws, &mut t),
                                    Act::I8(xt) => q.forward_q_into(xt, &mut ws, &mut t),
                                }
                                Act::F32(t)
                            }
                        }
                        None => Act::F32(plan.run(
                            inp.expect_f32(&node.name),
                            &params.weight,
                            &params.bias,
                        )),
                    }
                }
                Op::Relu => {
                    let mut t = acts[node.inputs[0]].expect_f32(&node.name).clone();
                    relu_inplace(&mut t);
                    Act::F32(t)
                }
                Op::MaxPool2 => {
                    let inp = acts[node.inputs[0]].expect_f32(&node.name);
                    let mut t = Tensor::zeros(&maxpool2_dims(inp));
                    maxpool2_into(inp, &mut t);
                    Act::F32(t)
                }
                Op::GlobalAvgPool => {
                    let inp = acts[node.inputs[0]].expect_f32(&node.name);
                    let mut t = Tensor::zeros(&gap_dims(inp));
                    global_avg_pool_into(inp, &mut t);
                    Act::F32(t)
                }
                Op::Linear { weight, bias } => {
                    let inp = acts[node.inputs[0]].expect_f32(&node.name);
                    let mut t = Tensor::zeros(&linear_dims(inp, weight));
                    linear_into(inp, weight, bias, &mut t);
                    Act::F32(t)
                }
                Op::Add => {
                    let mut t = acts[node.inputs[0]].expect_f32(&node.name).clone();
                    add_assign(&mut t, acts[node.inputs[1]].expect_f32(&node.name), &node.name);
                    Act::F32(t)
                }
                Op::AddRelu => {
                    let mut t = acts[node.inputs[0]].expect_f32(&node.name).clone();
                    add_relu_assign(
                        &mut t,
                        acts[node.inputs[1]].expect_f32(&node.name),
                        &node.name,
                    );
                    Act::F32(t)
                }
            };
            acts.push(out);
        }
        acts.into_iter()
            .map(|a| match a {
                Act::F32(t) => t,
                Act::I8(q) => q.dequantize(),
            })
            .collect()
    }

    /// Forward pass returning logits (last node's output flattened to
    /// [N, classes]). Runs through [`Model::forward_ws`] with a local
    /// workspace; inference servers keep a long-lived [`Workspace`] and
    /// call `forward_ws` directly for zero-alloc steady state.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut ws = Workspace::new();
        self.forward_ws(x, &mut ws)
    }

    /// Workspace-backed forward pass over a borrowed input: copies `x`
    /// into an arena buffer and delegates to
    /// [`Model::forward_ws_owned`]. Bit-identical to
    /// [`Model::forward_all`]'s final activation.
    pub fn forward_ws(&self, x: &Tensor, ws: &mut Workspace) -> Tensor {
        let mut t = ws_tensor(ws, &x.dims);
        t.data.copy_from_slice(&x.data);
        self.forward_ws_owned(t, ws)
    }

    /// Workspace-backed forward pass taking ownership of the input
    /// (single-`Op::Input` graphs — every model in this crate; callers
    /// feeding the input from the arena avoid a defensive copy). Every
    /// activation buffer is checked out of `ws`, dead activations are
    /// returned the moment their last consumer ran (ping-pong across a
    /// chain of layers), and single-use inputs of element-wise ops are
    /// mutated in place. After one warm-up call a reused workspace
    /// serves the whole pass without heap allocation. The returned
    /// tensor's buffer is owned by the caller (give it back to `ws` to
    /// recycle it).
    pub fn forward_ws_owned(&self, x: Tensor, ws: &mut Workspace) -> Tensor {
        // Liveness: the last node index consuming each activation.
        let mut last_use = vec![0usize; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                last_use[inp] = last_use[inp].max(i);
            }
        }
        let mut input = Some(x);
        let mut acts: Vec<Option<Act>> = (0..self.nodes.len()).map(|_| None).collect();
        for (i, node) in self.nodes.iter().enumerate() {
            let out = match &node.op {
                Op::Input => Act::F32(
                    input
                        .take()
                        .expect("forward_ws_owned supports one Input node; use forward_ws"),
                ),
                Op::Conv { params, plan, packed, quantized } => {
                    debug_assert_eq!(
                        (params.stride, params.pad),
                        (plan.desc.stride, plan.desc.pad),
                        "ConvParams and plan descriptor disagree at {}",
                        node.name
                    );
                    debug_assert_eq!(
                        params.weight.dims[1] * plan.desc.groups,
                        plan.desc.ic,
                        "weight grouping and plan descriptor disagree at {}",
                        node.name
                    );
                    let inp = acts[node.inputs[0]].as_ref().expect("SSA order");
                    match quantized {
                        Some(q) => {
                            let odims = q.out_dims_for(inp.dims());
                            if q.produces_q() {
                                // the compiled int8 link: emit codes on
                                // the consumer's grid, no f32 in between
                                let mut qt = ws_qtensor(ws, &odims);
                                match inp {
                                    Act::F32(t) => q.forward_into_q(t, ws, &mut qt),
                                    Act::I8(t) => q.forward_q_into_q(t, ws, &mut qt),
                                }
                                Act::I8(qt)
                            } else {
                                let mut out = ws_tensor(ws, &odims);
                                match inp {
                                    Act::F32(t) => q.forward_into(t, ws, &mut out),
                                    Act::I8(t) => q.forward_q_into(t, ws, &mut out),
                                }
                                Act::F32(out)
                            }
                        }
                        None => {
                            let xt = inp.expect_f32(&node.name);
                            let mut out = ws_tensor(ws, &plan.out_dims(xt, &params.weight));
                            match packed {
                                Some(p) => plan.run_packed_into(
                                    xt,
                                    &params.weight,
                                    p,
                                    &params.bias,
                                    ws,
                                    &mut out,
                                ),
                                None => {
                                    plan.run_into(xt, &params.weight, &params.bias, ws, &mut out)
                                }
                            }
                            Act::F32(out)
                        }
                    }
                }
                Op::Relu => {
                    let src = node.inputs[0];
                    let mut t = take_or_copy(&mut acts, src, last_use[src] == i, ws, &node.name);
                    relu_inplace(&mut t);
                    Act::F32(t)
                }
                Op::MaxPool2 => {
                    let inp = acts[node.inputs[0]].as_ref().expect("SSA order");
                    let inp = inp.expect_f32(&node.name);
                    let mut t = ws_tensor(ws, &maxpool2_dims(inp));
                    maxpool2_into(inp, &mut t);
                    Act::F32(t)
                }
                Op::GlobalAvgPool => {
                    let inp = acts[node.inputs[0]].as_ref().expect("SSA order");
                    let inp = inp.expect_f32(&node.name);
                    let mut t = ws_tensor(ws, &gap_dims(inp));
                    global_avg_pool_into(inp, &mut t);
                    Act::F32(t)
                }
                Op::Linear { weight, bias } => {
                    let inp = acts[node.inputs[0]].as_ref().expect("SSA order");
                    let inp = inp.expect_f32(&node.name);
                    let mut t = ws_tensor(ws, &linear_dims(inp, weight));
                    linear_into(inp, weight, bias, &mut t);
                    Act::F32(t)
                }
                Op::Add | Op::AddRelu => {
                    // Keep the a + b evaluation order of `forward_all`;
                    // reuse a's buffer when this is its last use.
                    let (ia, ib) = (node.inputs[0], node.inputs[1]);
                    let mut t =
                        take_or_copy(&mut acts, ia, last_use[ia] == i && ia != ib, ws, &node.name);
                    let b = acts[ib].as_ref().expect("SSA order").expect_f32(&node.name);
                    if matches!(node.op, Op::AddRelu) {
                        add_relu_assign(&mut t, b, &node.name);
                    } else {
                        add_assign(&mut t, b, &node.name);
                    }
                    Act::F32(t)
                }
            };
            // Recycle activations whose last consumer just ran (ones an
            // op already moved out of `acts` are skipped by the `take`).
            for &inp in &node.inputs {
                if last_use[inp] == i {
                    if let Some(dead) = acts[inp].take() {
                        give_act(ws, dead);
                    }
                }
            }
            acts[i] = Some(out);
        }
        let result = acts.pop().flatten().expect("model has at least one node");
        // Activations no node consumed (e.g. auxiliary heads) never hit
        // the last-use release above — recycle them so reuse stays
        // alloc-free and `in_use_bytes` returns to the output alone.
        for dead in acts.into_iter().flatten() {
            give_act(ws, dead);
        }
        if let Some(unused) = input.take() {
            ws.give_f32(unused.data);
        }
        match result {
            Act::F32(t) => t,
            // the int8-dataflow pass never requantizes a conv without
            // consumers, so an int8 model output cannot happen through
            // `compile` — decode defensively anyway
            Act::I8(q) => {
                let t = q.dequantize();
                ws.give_i8(q.data);
                t
            }
        }
    }

    /// Top-1 accuracy over a labelled batch.
    pub fn accuracy(&self, images: &Tensor, labels: &[u8]) -> f64 {
        let logits = self.forward(images);
        let n = logits.dims[0];
        let k: usize = logits.len() / n;
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits.data[i * k..(i + 1) * k];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if arg == labels[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}

/// Move the f32 activation `src` out of `acts` when this is its last
/// use (the in-place fast path), else copy it into a fresh workspace
/// tensor. Panics with context when the producer emitted int8 — the
/// compiler never routes int8 into element-wise ops.
fn take_or_copy(
    acts: &mut [Option<Act>],
    src: usize,
    movable: bool,
    ws: &mut Workspace,
    name: &str,
) -> Tensor {
    if movable {
        match acts[src].take().expect("SSA order") {
            Act::F32(t) => t,
            Act::I8(_) => panic!("{name}: op requires an f32 input but got an int8 activation"),
        }
    } else {
        let inp = acts[src].as_ref().expect("SSA order").expect_f32(name);
        let mut t = ws_tensor(ws, &inp.dims);
        t.data.copy_from_slice(&inp.data);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn toy_model() -> Model {
        let mut rng = Pcg32::seeded(99);
        let mut m = Model::new("toy");
        let inp = m.push(Op::Input, vec![], "input");
        let mut w = Tensor::zeros(&[4, 3, 3, 3]);
        rng.fill_gaussian(&mut w.data, 0.3);
        let desc = crate::engine::ConvDesc::new(2, 3, 4, 8, 8, 3, 1, 1);
        let c1 = m.push(
            Op::Conv {
                params: ConvParams { weight: w, bias: vec![0.0; 4], stride: 1, pad: 1 },
                plan: Arc::new(ConvPlan::direct(desc)),
                packed: None,
                quantized: None,
            },
            vec![inp],
            "conv1",
        );
        let r1 = m.push(Op::Relu, vec![c1], "relu1");
        let p = m.push(Op::GlobalAvgPool, vec![r1], "gap");
        let mut lw = Tensor::zeros(&[10, 4]);
        rng.fill_gaussian(&mut lw.data, 0.5);
        m.push(Op::Linear { weight: lw, bias: vec![0.1; 10] }, vec![p], "fc");
        m
    }

    #[test]
    fn forward_shapes() {
        let m = toy_model();
        let mut rng = Pcg32::seeded(7);
        let mut x = Tensor::zeros(&[2, 3, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let y = m.forward(&x);
        assert_eq!(y.dims, vec![2, 10, 1, 1]);
    }

    #[test]
    fn relu_and_add() {
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let r = m.push(Op::Relu, vec![i], "relu");
        m.push(Op::Add, vec![i, r], "add");
        let x = Tensor::from_vec(&[1, 1, 1, 3], vec![-1.0, 0.0, 2.0]);
        let y = m.forward(&x);
        assert_eq!(y.data, vec![-1.0, 0.0, 4.0]);
    }

    #[test]
    fn maxpool() {
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        m.push(Op::MaxPool2, vec![i], "mp");
        let x = Tensor::from_vec(&[1, 1, 2, 4], vec![1., 5., 2., 0., 3., 4., 1., 9.]);
        let y = m.forward(&x);
        assert_eq!(y.data, vec![5., 9.]);
    }

    #[test]
    fn accuracy_counts() {
        let m = toy_model();
        let mut rng = Pcg32::seeded(13);
        let mut x = Tensor::zeros(&[4, 3, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let logits = m.forward(&x);
        let labels: Vec<u8> = (0..4)
            .map(|i| {
                let row = &logits.data[i * 10..(i + 1) * 10];
                row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as u8
            })
            .collect();
        assert_eq!(m.accuracy(&x, &labels), 1.0);
    }

    #[test]
    fn forward_all_and_forward_agree() {
        let m = toy_model();
        let mut rng = Pcg32::seeded(14);
        let mut x = Tensor::zeros(&[2, 3, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let want = m.forward_all(&x).pop().unwrap();
        assert_eq!(m.forward(&x).data, want.data);
    }
}
