//! Tiny SSA graph IR for CNN inference.
//!
//! Each node consumes earlier node outputs by index; this is enough for
//! the ResNet family (residual adds) and VGG (pure chains) while keeping
//! forward execution trivially auditable for the PTQ experiments.

use super::tensor::Tensor;
use crate::engine::ConvPlan;
use crate::quant::qconv::QConvLayer;
use std::sync::Arc;

/// One conv layer's parameters (BN already folded at export time).
#[derive(Clone, Debug)]
pub struct ConvParams {
    pub weight: Tensor, // OC×IC×R×R
    pub bias: Vec<f32>,
    pub stride: usize,
    pub pad: usize,
}

pub enum Op {
    /// Graph input placeholder.
    Input,
    Conv {
        params: ConvParams,
        /// engine-selected execution plan (see [`crate::engine`])
        plan: Arc<ConvPlan>,
        /// set by the PTQ pass: quantized executor overriding `plan`
        quantized: Option<QConvLayer>,
    },
    Relu,
    /// 2×2 max-pool, stride 2.
    MaxPool2,
    GlobalAvgPool,
    Linear {
        weight: Tensor, // OUT×IN
        bias: Vec<f32>,
    },
    /// Element-wise sum of the two inputs (residual join).
    Add,
}

pub struct Node {
    pub op: Op,
    pub inputs: Vec<usize>,
    pub name: String,
}

pub struct Model {
    pub nodes: Vec<Node>,
    pub name: String,
}

impl Model {
    pub fn new(name: &str) -> Model {
        Model { nodes: Vec::new(), name: name.into() }
    }

    pub fn push(&mut self, op: Op, inputs: Vec<usize>, name: impl Into<String>) -> usize {
        self.nodes.push(Node { op, inputs, name: name.into() });
        self.nodes.len() - 1
    }

    /// Indices of all conv nodes (the layers PTQ operates on).
    pub fn conv_nodes(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, Op::Conv { .. }))
            .map(|(i, _)| i)
            .collect()
    }

    /// Forward pass; returns every node's activation (used by PTQ
    /// calibration and the Fig.-3/Fig.-5 per-layer probes).
    pub fn forward_all(&self, x: &Tensor) -> Vec<Tensor> {
        let mut acts: Vec<Tensor> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let get = |i: usize| -> &Tensor { &acts[i] };
            let out = match &node.op {
                Op::Input => x.clone(),
                Op::Conv { params, plan, quantized } => {
                    debug_assert_eq!(
                        (params.stride, params.pad),
                        (plan.desc.stride, plan.desc.pad),
                        "ConvParams and plan descriptor disagree at {}",
                        node.name
                    );
                    let inp = get(node.inputs[0]);
                    if let Some(q) = quantized {
                        q.forward(inp)
                    } else {
                        plan.run(inp, &params.weight, &params.bias)
                    }
                }
                Op::Relu => {
                    let mut t = get(node.inputs[0]).clone();
                    for v in t.data.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                    t
                }
                Op::MaxPool2 => {
                    let inp = get(node.inputs[0]);
                    let (n, c, h, w) = inp.dims4();
                    let (oh, ow) = (h / 2, w / 2);
                    let mut t = Tensor::zeros(&[n, c, oh, ow]);
                    for ni in 0..n {
                        for ci in 0..c {
                            let src = inp.plane(ni, ci);
                            let dst = t.plane_mut(ni, ci);
                            for y in 0..oh {
                                for x2 in 0..ow {
                                    let m = src[2 * y * w + 2 * x2]
                                        .max(src[2 * y * w + 2 * x2 + 1])
                                        .max(src[(2 * y + 1) * w + 2 * x2])
                                        .max(src[(2 * y + 1) * w + 2 * x2 + 1]);
                                    dst[y * ow + x2] = m;
                                }
                            }
                        }
                    }
                    t
                }
                Op::GlobalAvgPool => {
                    let inp = get(node.inputs[0]);
                    let (n, c, h, w) = inp.dims4();
                    let mut t = Tensor::zeros(&[n, c, 1, 1]);
                    for ni in 0..n {
                        for ci in 0..c {
                            let s: f32 = inp.plane(ni, ci).iter().sum();
                            *t.at4_mut(ni, ci, 0, 0) = s / (h * w) as f32;
                        }
                    }
                    t
                }
                Op::Linear { weight, bias } => {
                    let inp = get(node.inputs[0]);
                    let n = inp.dims[0];
                    let in_dim: usize = inp.dims[1..].iter().product();
                    let out_dim = weight.dims[0];
                    assert_eq!(weight.dims[1], in_dim);
                    let mut t = Tensor::zeros(&[n, out_dim, 1, 1]);
                    for ni in 0..n {
                        let xrow = &inp.data[ni * in_dim..(ni + 1) * in_dim];
                        for o in 0..out_dim {
                            let wrow = &weight.data[o * in_dim..(o + 1) * in_dim];
                            let mut acc = if bias.is_empty() { 0.0 } else { bias[o] };
                            for (a, b) in xrow.iter().zip(wrow) {
                                acc += a * b;
                            }
                            *t.at4_mut(ni, o, 0, 0) = acc;
                        }
                    }
                    t
                }
                Op::Add => {
                    let a = get(node.inputs[0]);
                    let b = get(node.inputs[1]);
                    assert_eq!(a.dims, b.dims, "residual shape mismatch at {}", node.name);
                    let mut t = a.clone();
                    for (x2, y) in t.data.iter_mut().zip(&b.data) {
                        *x2 += y;
                    }
                    t
                }
            };
            acts.push(out);
        }
        acts
    }

    /// Forward pass returning logits (last node's output flattened to
    /// [N, classes]).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_all(x).pop().unwrap()
    }

    /// Top-1 accuracy over a labelled batch.
    pub fn accuracy(&self, images: &Tensor, labels: &[u8]) -> f64 {
        let logits = self.forward(images);
        let n = logits.dims[0];
        let k: usize = logits.len() / n;
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits.data[i * k..(i + 1) * k];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if arg == labels[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn toy_model() -> Model {
        let mut rng = Pcg32::seeded(99);
        let mut m = Model::new("toy");
        let inp = m.push(Op::Input, vec![], "input");
        let mut w = Tensor::zeros(&[4, 3, 3, 3]);
        rng.fill_gaussian(&mut w.data, 0.3);
        let desc = crate::engine::ConvDesc::new(2, 3, 4, 8, 8, 3, 1, 1);
        let c1 = m.push(
            Op::Conv {
                params: ConvParams { weight: w, bias: vec![0.0; 4], stride: 1, pad: 1 },
                plan: Arc::new(ConvPlan::direct(desc)),
                quantized: None,
            },
            vec![inp],
            "conv1",
        );
        let r1 = m.push(Op::Relu, vec![c1], "relu1");
        let p = m.push(Op::GlobalAvgPool, vec![r1], "gap");
        let mut lw = Tensor::zeros(&[10, 4]);
        rng.fill_gaussian(&mut lw.data, 0.5);
        m.push(Op::Linear { weight: lw, bias: vec![0.1; 10] }, vec![p], "fc");
        m
    }

    #[test]
    fn forward_shapes() {
        let m = toy_model();
        let mut rng = Pcg32::seeded(7);
        let mut x = Tensor::zeros(&[2, 3, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let y = m.forward(&x);
        assert_eq!(y.dims, vec![2, 10, 1, 1]);
    }

    #[test]
    fn relu_and_add() {
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let r = m.push(Op::Relu, vec![i], "relu");
        m.push(Op::Add, vec![i, r], "add");
        let x = Tensor::from_vec(&[1, 1, 1, 3], vec![-1.0, 0.0, 2.0]);
        let y = m.forward(&x);
        assert_eq!(y.data, vec![-1.0, 0.0, 4.0]);
    }

    #[test]
    fn maxpool() {
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        m.push(Op::MaxPool2, vec![i], "mp");
        let x = Tensor::from_vec(&[1, 1, 2, 4], vec![1., 5., 2., 0., 3., 4., 1., 9.]);
        let y = m.forward(&x);
        assert_eq!(y.data, vec![5., 9.]);
    }

    #[test]
    fn accuracy_counts() {
        let m = toy_model();
        let mut rng = Pcg32::seeded(13);
        let mut x = Tensor::zeros(&[4, 3, 8, 8]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let logits = m.forward(&x);
        let labels: Vec<u8> = (0..4)
            .map(|i| {
                let row = &logits.data[i * 10..(i + 1) * 10];
                row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as u8
            })
            .collect();
        assert_eq!(m.accuracy(&x, &labels), 1.0);
    }
}
