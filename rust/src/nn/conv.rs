//! Convolution executors: direct and tiled fast convolution (Eq. 1).
//!
//! The fast path is organized exactly like the paper's (and the Pallas
//! kernel's) dataflow: gather L×L input tiles → Bᵀ·x·B per channel
//! (addition network) → per-frequency GEMM over channels
//! ([tiles×Cin]·[Cin×Cout] for each of the T² transform points, executed
//! by the blocked [`crate::linalg::gemm`] core) → Aᵀ·(·)·A → scatter M×M
//! output tiles. The `*_into` entry points run entirely out of a caller
//! [`Workspace`] and write straight into the caller's output tensor —
//! zero heap allocation in steady state. The transform-domain-quantized
//! variant (Eq. 17) lives in [`crate::quant`] and reuses this module's
//! tiling machinery.

use super::tensor::Tensor;
use crate::algo::Bilinear;
use crate::engine::{Epilogue, Workspace};
use crate::linalg::gemm::{
    gemm_packed_f32, pack_b_f32, pack_b_i8, packed_b_f32_len, packed_b_i8_len,
};
use crate::util::par::{num_threads, par_chunks_mut, par_chunks_states};

/// Lane width of the batched tile transforms (`transform_tiles8` /
/// `inverse_tiles8` process 8 tiles per sweep; equals the packed-GEMM
/// panel width, so one tile group feeds one output panel).
pub const TILE_LANES: usize = 8;

/// Precomputed matrices for a tiled fast convolution.
#[derive(Debug)]
pub struct FastConvPlan {
    /// the exact bilinear algorithm the matrices were lowered from
    pub algo: Bilinear,
    /// Bᵀ as f32, T×L row-major
    pub bt: Vec<f32>,
    /// Aᵀ as f32, M×T row-major
    pub at: Vec<f32>,
    /// G as f32, T×R row-major
    pub g: Vec<f32>,
}

impl FastConvPlan {
    /// Lower a bilinear algorithm's matrices to f32 once.
    pub fn new(algo: Bilinear) -> FastConvPlan {
        let bt = algo.bt.to_f32_vec();
        let at = algo.at.to_f32_vec();
        let g = algo.g.to_f32_vec();
        FastConvPlan { algo, bt, at, g }
    }

    /// Transform points per axis (T).
    pub fn t(&self) -> usize {
        self.algo.t
    }

    /// Output tile edge (M).
    pub fn m(&self) -> usize {
        self.algo.m
    }

    /// Kernel size (R).
    pub fn r(&self) -> usize {
        self.algo.r
    }

    /// Input tile edge (L = M + R − 1).
    pub fn l(&self) -> usize {
        self.algo.input_len()
    }

    /// Transform one R×R filter: U = G·f·Gᵀ (T×T), written into `u`.
    /// `tmp` must hold T×R floats.
    pub fn transform_filter_into(&self, f: &[f32], tmp: &mut [f32], u: &mut [f32]) {
        let (t, r) = (self.t(), self.r());
        assert_eq!(f.len(), r * r);
        // tmp = G·f  (t×r)
        for v in tmp.iter_mut().take(t * r) {
            *v = 0.0;
        }
        for i in 0..t {
            for k in 0..r {
                let gv = self.g[i * r + k];
                if gv != 0.0 {
                    for j in 0..r {
                        tmp[i * r + j] += gv * f[k * r + j];
                    }
                }
            }
        }
        // U = tmp·Gᵀ (t×t)
        for i in 0..t {
            for j in 0..t {
                let mut acc = 0f32;
                for k in 0..r {
                    acc += tmp[i * r + k] * self.g[j * r + k];
                }
                u[i * t + j] = acc;
            }
        }
    }

    /// Transform one R×R filter: U = G·f·Gᵀ (T×T).
    pub fn transform_filter(&self, f: &[f32]) -> Vec<f32> {
        let (t, r) = (self.t(), self.r());
        let mut tmp = vec![0f32; t * r];
        let mut u = vec![0f32; t * t];
        self.transform_filter_into(f, &mut tmp, &mut u);
        u
    }

    /// Transform all filters into freq-major layout [T²][OC][IC], using
    /// caller scratch: `tmp` holds T×R floats, `utile` holds T×T.
    pub fn transform_weights_into(
        &self,
        w: &[f32],
        oc: usize,
        ic: usize,
        tmp: &mut [f32],
        utile: &mut [f32],
        out: &mut [f32],
    ) {
        let t = self.t();
        let r = self.r();
        assert!(out.len() >= t * t * oc * ic);
        for o in 0..oc {
            for i in 0..ic {
                let f = &w[(o * ic + i) * r * r..(o * ic + i + 1) * r * r];
                self.transform_filter_into(f, tmp, utile);
                for uv in 0..t * t {
                    out[(uv * oc + o) * ic + i] = utile[uv];
                }
            }
        }
    }

    /// Transform all filters: returns freq-major layout [T²][OC][IC].
    pub fn transform_weights(&self, w: &[f32], oc: usize, ic: usize) -> Vec<f32> {
        let t = self.t();
        let mut tmp = vec![0f32; t * self.r()];
        let mut utile = vec![0f32; t * t];
        let mut out = vec![0f32; t * t * oc * ic];
        self.transform_weights_into(w, oc, ic, &mut tmp, &mut utile, &mut out);
        out
    }

    /// Transform one L×L input tile: V = Bᵀ·x·B (T×T), into `out`.
    /// `scratch` must hold T×L floats.
    pub fn transform_tile(&self, tile: &[f32], scratch: &mut [f32], out: &mut [f32]) {
        let (t, l) = (self.t(), self.l());
        debug_assert_eq!(tile.len(), l * l);
        // scratch = Bᵀ·x (t×l)
        for v in scratch.iter_mut().take(t * l) {
            *v = 0.0;
        }
        for i in 0..t {
            for k in 0..l {
                let bv = self.bt[i * l + k];
                if bv != 0.0 {
                    let src = &tile[k * l..(k + 1) * l];
                    let dst = &mut scratch[i * l..(i + 1) * l];
                    if bv == 1.0 {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    } else if bv == -1.0 {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d -= s;
                        }
                    } else {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += bv * s;
                        }
                    }
                }
            }
        }
        // out = scratch·B (t×t): out[i][j] = Σ_k scratch[i][k]·Bᵀ[j][k]
        for i in 0..t {
            for j in 0..t {
                let mut acc = 0f32;
                for k in 0..l {
                    let bv = self.bt[j * l + k];
                    if bv != 0.0 {
                        acc += scratch[i * l + k] * bv;
                    }
                }
                out[i * t + j] = acc;
            }
        }
    }

    /// Transform a lane-batched group of up to [`TILE_LANES`] L×L input
    /// tiles at once: per lane, exactly the operation sequence of
    /// [`FastConvPlan::transform_tile`], so batched and single-tile
    /// results are bit-identical. Buffers are lane-major:
    /// `tiles[(i·L+j)·8 + lane]`; `scratch` holds T×L×8 floats, `out`
    /// T×T×8. The add-only ±1 rows of Bᵀ become pure 8-lane add/sub
    /// sweeps, which is what lets the compiler vectorize the transform.
    pub fn transform_tiles8(&self, tiles: &[f32], scratch: &mut [f32], out: &mut [f32]) {
        let (t, l) = (self.t(), self.l());
        let lw = TILE_LANES;
        debug_assert!(tiles.len() >= l * l * lw);
        for v in scratch.iter_mut().take(t * l * lw) {
            *v = 0.0;
        }
        for i in 0..t {
            for k in 0..l {
                let bv = self.bt[i * l + k];
                if bv != 0.0 {
                    let (ds, de) = (i * l * lw, (i + 1) * l * lw);
                    let src = &tiles[k * l * lw..(k + 1) * l * lw];
                    let dst = &mut scratch[ds..de];
                    if bv == 1.0 {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    } else if bv == -1.0 {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d -= s;
                        }
                    } else {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += bv * s;
                        }
                    }
                }
            }
        }
        for i in 0..t {
            for j in 0..t {
                let mut acc = [0f32; TILE_LANES];
                for k in 0..l {
                    let bv = self.bt[j * l + k];
                    if bv != 0.0 {
                        let src = &scratch[(i * l + k) * lw..(i * l + k + 1) * lw];
                        for (a, s) in acc.iter_mut().zip(src) {
                            *a += s * bv;
                        }
                    }
                }
                out[(i * t + j) * lw..(i * t + j + 1) * lw].copy_from_slice(&acc);
            }
        }
    }

    /// Inverse transform a T×T product block: Y = Aᵀ·p·A (M×M).
    pub fn inverse_tile(&self, p: &[f32], scratch: &mut [f32], out: &mut [f32]) {
        let (t, m) = (self.t(), self.m());
        // scratch = Aᵀ·p (m×t)
        for v in scratch.iter_mut().take(m * t) {
            *v = 0.0;
        }
        for i in 0..m {
            for k in 0..t {
                let av = self.at[i * t + k];
                if av != 0.0 {
                    let src = &p[k * t..(k + 1) * t];
                    let dst = &mut scratch[i * t..(i + 1) * t];
                    for (d, s) in dst.iter_mut().zip(src) {
                        *d += av * s;
                    }
                }
            }
        }
        // out = scratch·A (m×m)
        for i in 0..m {
            for j in 0..m {
                let mut acc = 0f32;
                for k in 0..t {
                    let av = self.at[j * t + k];
                    if av != 0.0 {
                        acc += scratch[i * t + k] * av;
                    }
                }
                out[i * m + j] = acc;
            }
        }
    }

    /// Inverse-transform a lane-batched group of up to [`TILE_LANES`]
    /// T×T product blocks at once (lane-major buffers, per-lane
    /// bit-identical to [`FastConvPlan::inverse_tile`]). `scratch`
    /// holds M×T×8 floats, `out` M×M×8.
    pub fn inverse_tiles8(&self, p8: &[f32], scratch: &mut [f32], out: &mut [f32]) {
        let (t, m) = (self.t(), self.m());
        let lw = TILE_LANES;
        debug_assert!(p8.len() >= t * t * lw);
        for v in scratch.iter_mut().take(m * t * lw) {
            *v = 0.0;
        }
        for i in 0..m {
            for k in 0..t {
                let av = self.at[i * t + k];
                if av != 0.0 {
                    let src = &p8[k * t * lw..(k + 1) * t * lw];
                    let dst = &mut scratch[i * t * lw..(i + 1) * t * lw];
                    for (d, s) in dst.iter_mut().zip(src) {
                        *d += av * s;
                    }
                }
            }
        }
        for i in 0..m {
            for j in 0..m {
                let mut acc = [0f32; TILE_LANES];
                for k in 0..t {
                    let av = self.at[j * t + k];
                    if av != 0.0 {
                        let src = &scratch[(i * t + k) * lw..(i * t + k + 1) * lw];
                        for (a, s) in acc.iter_mut().zip(src) {
                            *a += s * av;
                        }
                    }
                }
                out[(i * m + j) * lw..(i * m + j + 1) * lw].copy_from_slice(&acc);
            }
        }
    }
}

/// Grouped direct correlation with stride and symmetric zero padding,
/// written into `out` (shape `[N, OC, OH, OW]`). The weight tensor is
/// `[OC, IC/groups, R, R]`; output channel `o` reduces over input
/// channels of its group only (`groups == ic` is depthwise).
/// Allocation-free: each output plane is accumulated in place by its
/// worker. With `groups == 1` this is bit-identical to the historical
/// dense kernel. The fused epilogue `ep` is applied at output-write
/// time (bit-identical to a separate ReLU pass over the unfused
/// output).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_direct_grouped_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    pad: usize,
    groups: usize,
    ep: Epilogue,
    out: &mut Tensor,
) {
    conv2d_direct_dilated_into(x, w, bias, stride, pad, groups, 1, ep, out);
}

/// Grouped direct correlation with kernel dilation: tap `(ky, kx)`
/// reads input offset `(ky·dilation, kx·dilation)`, so the receptive
/// field spans `(r−1)·dilation + 1` pixels per axis. At `dilation == 1`
/// the loop arithmetic reduces to exactly the undilated kernel's, so
/// [`conv2d_direct_grouped_into`] (which delegates here) is
/// bit-identical to its historical output. This is the float reference
/// every dilated engine path is tested against.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_direct_dilated_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    pad: usize,
    groups: usize,
    dilation: usize,
    ep: Epilogue,
    out: &mut Tensor,
) {
    let (n, ic, h, wid) = x.dims4();
    let (oc, icg, r, r2) = w.dims4();
    assert_eq!(r, r2, "square kernels only");
    assert!(groups >= 1 && oc % groups == 0, "groups {groups} must divide oc {oc}");
    assert_eq!(icg * groups, ic, "weight channels {icg}×{groups} groups vs input {ic}");
    assert!(bias.is_empty() || bias.len() == oc);
    assert!(dilation >= 1, "dilation must be >= 1");
    let ocg = oc / groups;
    let er = (r - 1) * dilation + 1;
    let oh = (h + 2 * pad - er) / stride + 1;
    let ow = (wid + 2 * pad - er) / stride + 1;
    out.assert_dims(&[n, oc, oh, ow]);
    par_chunks_mut(&mut out.data, oh * ow, |job, plane| {
        let (ni, o) = (job / oc, job % oc);
        let gi = o / ocg;
        plane.fill(0.0);
        for il in 0..icg {
            let xp = x.plane(ni, gi * icg + il);
            let wp = w.plane(o, il);
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0f32;
                    for ky in 0..r {
                        let yy = oy * stride + ky * dilation;
                        if yy < pad || yy >= h + pad {
                            continue;
                        }
                        let yy = yy - pad;
                        for kx in 0..r {
                            let xx = ox * stride + kx * dilation;
                            if xx < pad || xx >= wid + pad {
                                continue;
                            }
                            acc += wp[ky * r + kx] * xp[yy * wid + (xx - pad)];
                        }
                    }
                    plane[oy * ow + ox] += acc;
                }
            }
        }
        let b = if bias.is_empty() { 0.0 } else { bias[o] };
        for v in plane.iter_mut() {
            *v = ep.apply(*v + b);
        }
    });
}

/// Dense direct correlation into `out` — [`conv2d_direct_grouped_into`]
/// at `groups == 1`.
pub fn conv2d_direct_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    pad: usize,
    out: &mut Tensor,
) {
    conv2d_direct_grouped_into(x, w, bias, stride, pad, 1, Epilogue::None, out);
}

/// Grouped direct correlation (allocating wrapper).
pub fn conv2d_direct_grouped(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    pad: usize,
    groups: usize,
) -> Tensor {
    let (n, _, h, wid) = x.dims4();
    let (oc, _, r, _) = w.dims4();
    let oh = (h + 2 * pad - r) / stride + 1;
    let ow = (wid + 2 * pad - r) / stride + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    conv2d_direct_grouped_into(x, w, bias, stride, pad, groups, Epilogue::None, &mut out);
    out
}

/// Direct correlation with stride and symmetric zero padding. Like the
/// other allocating wrappers, the group count is inferred from the
/// weight shape (`groups = IC / weight IC`; dense weights give 1) —
/// the crate-wide convention that `[OC, IC/g, R, R]` encodes grouping.
pub fn conv2d_direct(x: &Tensor, w: &Tensor, bias: &[f32], stride: usize, pad: usize) -> Tensor {
    let (_, ic, _, _) = x.dims4();
    let icg = w.dims[1];
    assert!(icg >= 1 && ic % icg == 0, "weight channels {icg} must divide input channels {ic}");
    conv2d_direct_grouped(x, w, bias, stride, pad, ic / icg)
}

/// Gather the L×L input tile for output tile (ty, tx) of image n, channel c
/// (stride-1 fast path, zero padding `pad`).
#[inline]
pub fn gather_tile(
    x: &Tensor,
    n: usize,
    c: usize,
    ty: usize,
    tx: usize,
    m: usize,
    l: usize,
    pad: usize,
    out: &mut [f32],
) {
    let (_, _, h, w) = x.dims4();
    let plane = x.plane(n, c);
    let y0 = (ty * m) as isize - pad as isize;
    let x0 = (tx * m) as isize - pad as isize;
    for i in 0..l {
        let yy = y0 + i as isize;
        for j in 0..l {
            let xx = x0 + j as isize;
            out[i * l + j] = if yy >= 0 && (yy as usize) < h && xx >= 0 && (xx as usize) < w {
                plane[yy as usize * w + xx as usize]
            } else {
                0.0
            };
        }
    }
}

/// Gather up to [`TILE_LANES`] consecutive tiles (row-major tile
/// indices `base..base+lanes`) of image `n`, channel `c` into the
/// lane-major batch buffer `out[(i·L+j)·8 + lane]` (stride-1 fast path,
/// zero padding `pad`). Lanes ≥ `lanes` keep their previous contents —
/// the batched transforms compute and discard those lanes.
#[allow(clippy::too_many_arguments)]
pub fn gather_tiles8(
    x: &Tensor,
    n: usize,
    c: usize,
    base: usize,
    lanes: usize,
    tiles_x: usize,
    m: usize,
    l: usize,
    pad: usize,
    out: &mut [f32],
) {
    let (_, _, h, w) = x.dims4();
    let plane = x.plane(n, c);
    for lane in 0..lanes {
        let tile_idx = base + lane;
        let (ty, tx) = (tile_idx / tiles_x, tile_idx % tiles_x);
        let y0 = (ty * m) as isize - pad as isize;
        let x0 = (tx * m) as isize - pad as isize;
        for i in 0..l {
            let yy = y0 + i as isize;
            for j in 0..l {
                let xx = x0 + j as isize;
                out[(i * l + j) * TILE_LANES + lane] =
                    if yy >= 0 && (yy as usize) < h && xx >= 0 && (xx as usize) < w {
                        plane[yy as usize * w + xx as usize]
                    } else {
                        0.0
                    };
            }
        }
    }
}

/// Per-worker scratch for the tiled fast path, checked out of a
/// [`Workspace`] before the parallel region and returned after. The
/// per-tile buffers are lane-batched ([`TILE_LANES`] tiles wide).
struct FastScratch {
    /// V blocks, freq-major [T²][tiles][IC]
    v: Vec<f32>,
    /// P blocks, freq-major [T²][tiles][OC]
    p: Vec<f32>,
    /// gathered L×L input tiles, lane-major [L²][8]
    tile: Vec<f32>,
    /// Bᵀ·x intermediate (T×L×8)
    tscr: Vec<f32>,
    /// one transformed tile group (T×T×8)
    tv: Vec<f32>,
    /// one tile group's ⊙ products (T×T×8)
    prod: Vec<f32>,
    /// Aᵀ·p intermediate (M×T×8)
    iscr: Vec<f32>,
    /// M×M output tiles, lane-major (M²×8)
    ytile: Vec<f32>,
}

impl FastScratch {
    #[allow(clippy::too_many_arguments)]
    fn take(
        ws: &mut Workspace,
        tt: usize,
        n_tiles: usize,
        ic: usize,
        oc: usize,
        m: usize,
        l: usize,
        t: usize,
    ) -> FastScratch {
        FastScratch {
            v: ws.take_f32(tt * n_tiles * ic),
            p: ws.take_f32(tt * n_tiles * oc),
            tile: ws.take_f32(l * l * TILE_LANES),
            tscr: ws.take_f32(t * l * TILE_LANES),
            tv: ws.take_f32(tt * TILE_LANES),
            prod: ws.take_f32(tt * TILE_LANES),
            iscr: ws.take_f32(m * t * TILE_LANES),
            ytile: ws.take_f32(m * m * TILE_LANES),
        }
    }

    fn give(self, ws: &mut Workspace) {
        ws.give_f32(self.v);
        ws.give_f32(self.p);
        ws.give_f32(self.tile);
        ws.give_f32(self.tscr);
        ws.give_f32(self.tv);
        ws.give_f32(self.prod);
        ws.give_f32(self.iscr);
        ws.give_f32(self.ytile);
    }
}

/// Tiled fast convolution (stride 1), float transform domain, executed
/// out of `ws` into `out`: gather all tiles → batched Bᵀ·x·B → one
/// [tiles×IC/g]·[IC/g×OC/g] GEMM per (transform point, group) →
/// batched Aᵀ·(·)·A → scatter. The weight tensor is
/// `[OC, IC/groups, R, R]`; SFC's per-frequency structure applies
/// per-group unchanged, each group just runs a smaller channel
/// reduction. All data buffers come from `ws` — zero workspace heap
/// allocation once the arena is warm. At `groups == 1` the indexing
/// degenerates to the historical dense layout, bit-identically.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fast_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    plan: &FastConvPlan,
    pad: usize,
    groups: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let (_, ic, _, _) = x.dims4();
    let (oc, icg, r, _) = w.dims4();
    assert!(groups >= 1 && oc % groups == 0, "groups {groups} must divide oc {oc}");
    assert_eq!(icg * groups, ic, "weight channels {icg}×{groups} groups vs input {ic}");
    assert_eq!(r, plan.r());
    let ocg = oc / groups;
    let (t, tt) = (plan.t(), plan.t() * plan.t());
    // Transform weights (freq-major [T²][OC][IC/g], output channels
    // contiguous per group) and pack each (frequency, group) block into
    // the GEMM panel layout — the per-call twin of the plan-time
    // pre-packing in `engine::PackedWeights` (bit-identical results).
    let blk = packed_b_f32_len(ocg, icg);
    let mut u = ws.take_f32(tt * oc * icg);
    let mut up = ws.take_f32(tt * groups * blk);
    {
        let mut tmp = ws.take_f32(t * r);
        let mut utile = ws.take_f32(tt);
        plan.transform_weights_into(&w.data, oc, icg, &mut tmp, &mut utile, &mut u);
        ws.give_f32(tmp);
        ws.give_f32(utile);
    }
    pack_fast_weights(&u, oc, icg, groups, tt, &mut up);
    ws.give_f32(u);
    conv2d_fast_packed_into(x, &up, oc, icg, bias, plan, pad, groups, ep, ws, out);
    ws.give_f32(up);
}

/// Pack transformed weights (freq-major `[T²][OC][IC/g]`, output
/// channels contiguous per group) into per-(frequency, group) GEMM B
/// panels — the layout [`conv2d_fast_packed_into`] consumes. `up` must
/// hold `T²·groups·packed_b_f32_len(OC/g, IC/g)` floats.
pub fn pack_fast_weights(
    u: &[f32],
    oc: usize,
    icg: usize,
    groups: usize,
    tt: usize,
    up: &mut [f32],
) {
    let ocg = oc / groups;
    let blk = packed_b_f32_len(ocg, icg);
    assert!(up.len() >= tt * groups * blk);
    for uv in 0..tt {
        for gi in 0..groups {
            let rows = &u[(uv * oc + gi * ocg) * icg..(uv * oc + (gi + 1) * ocg) * icg];
            let dst = &mut up[(uv * groups + gi) * blk..(uv * groups + gi + 1) * blk];
            pack_b_f32(ocg, icg, rows, dst);
        }
    }
}

/// The int8 twin of [`pack_fast_weights`]: pack quantized transformed
/// weights (freq-major `[T²][OC][IC/g]`) into per-(frequency, group)
/// interleaved-k-pair GEMM B panels. `up` must hold
/// `T²·groups·packed_b_i8_len(OC/g, IC/g)` bytes. The group-major block
/// order matches the f32 layout, so the two packers cannot drift apart.
pub fn pack_fast_weights_i8(
    u: &[i8],
    oc: usize,
    icg: usize,
    groups: usize,
    tt: usize,
    up: &mut [i8],
) {
    let ocg = oc / groups;
    let blk = packed_b_i8_len(ocg, icg);
    assert!(up.len() >= tt * groups * blk);
    for uv in 0..tt {
        for gi in 0..groups {
            let rows = &u[(uv * oc + gi * ocg) * icg..(uv * oc + (gi + 1) * ocg) * icg];
            let dst = &mut up[(uv * groups + gi) * blk..(uv * groups + gi + 1) * blk];
            pack_b_i8(ocg, icg, rows, dst);
        }
    }
}

/// The packed-weight fast-conv core: like [`conv2d_fast_into`] but the
/// weights arrive pre-transformed and pre-packed (`up`, laid out by
/// [`pack_fast_weights`] — what a cached
/// [`crate::engine::PackedWeights`] holds), so a steady-state call
/// touches only packed operands. Stage 1 gathers and transforms tiles
/// in lane batches of [`TILE_LANES`], stage 2 runs the dispatched
/// packed GEMM per (frequency, group), stage 3 inverse-transforms lane
/// batches and scatters. Bit-identical to [`conv2d_fast_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fast_packed_into(
    x: &Tensor,
    up: &[f32],
    oc: usize,
    icg: usize,
    bias: &[f32],
    plan: &FastConvPlan,
    pad: usize,
    groups: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let (n, ic, h, wid) = x.dims4();
    assert!(groups >= 1 && oc % groups == 0, "groups {groups} must divide oc {oc}");
    assert_eq!(icg * groups, ic, "weight channels {icg}×{groups} groups vs input {ic}");
    assert!(bias.is_empty() || bias.len() == oc);
    let ocg = oc / groups;
    let r = plan.r();
    let (m, l, t) = (plan.m(), plan.l(), plan.t());
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    out.assert_dims(&[n, oc, oh, ow]);
    let tiles_y = oh.div_ceil(m);
    let tiles_x = ow.div_ceil(m);
    let n_tiles = tiles_y * tiles_x;
    let ntg = n_tiles.div_ceil(TILE_LANES);
    let tt = t * t;
    let blk = packed_b_f32_len(ocg, icg);
    assert!(up.len() >= tt * groups * blk, "packed weights too small");

    // One scratch set per worker; images are distributed contiguously and
    // each worker writes its images' output chunks directly (no mutex).
    // The per-(freq,group) GEMMs below may additionally thread over rows
    // when large enough — the CoreBudget arbitrates, so batch-level
    // workers and intra-op GEMM teams share one lane pool.
    let workers = num_threads().min(n).max(1);
    let mut states: Vec<FastScratch> =
        (0..workers).map(|_| FastScratch::take(ws, tt, n_tiles, ic, oc, m, l, t)).collect();
    let img_len = oc * oh * ow;
    par_chunks_states(&mut out.data, img_len, &mut states, |st, ni, out_img| {
        // 1) gather + transform tile groups (8 lanes): V group-major
        //    [T²][G][tiles][IC/g] (== [T²][tiles][IC] when groups == 1)
        for tg in 0..ntg {
            let base = tg * TILE_LANES;
            let lanes = (n_tiles - base).min(TILE_LANES);
            for c in 0..ic {
                let (gi, il) = (c / icg, c % icg);
                gather_tiles8(x, ni, c, base, lanes, tiles_x, m, l, pad, &mut st.tile);
                plan.transform_tiles8(&st.tile, &mut st.tscr, &mut st.tv);
                for uv in 0..tt {
                    let row = ((uv * groups + gi) * n_tiles + base) * icg + il;
                    for lane in 0..lanes {
                        st.v[row + lane * icg] = st.tv[uv * TILE_LANES + lane];
                    }
                }
            }
        }
        // 2) per-(frequency, group) packed GEMM (runtime-dispatched):
        //    P[uv][g] = V[uv][g] · U[uv][g]ᵀ ([tiles×IC/g]·[IC/g×OC/g]).
        //    The tt·groups products are independent (disjoint P blocks,
        //    job = uv·groups + gi), so they go out as one batched pool
        //    submit — stealable tasks instead of a serial loop. When
        //    this image worker already holds the only budget lane the
        //    helper degrades to the same serial job order.
        let v = &st.v;
        let pblocks = &mut st.p[..tt * groups * n_tiles * ocg];
        par_chunks_mut(pblocks, n_tiles * ocg, |job, pblk| {
            let vb = job * n_tiles * icg;
            let ub = job * blk;
            let vblk = &v[vb..vb + n_tiles * icg];
            let ublk = &up[ub..ub + blk];
            gemm_packed_f32(n_tiles, ocg, icg, vblk, ublk, pblk);
        });
        // 3) lane-batched inverse transform + scatter into this image's
        //    output chunk
        for o in 0..oc {
            let (gi, ol) = (o / ocg, o % ocg);
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            let plane = &mut out_img[o * oh * ow..(o + 1) * oh * ow];
            for tg in 0..ntg {
                let base = tg * TILE_LANES;
                let lanes = (n_tiles - base).min(TILE_LANES);
                for uv in 0..tt {
                    let row = ((uv * groups + gi) * n_tiles + base) * ocg + ol;
                    for lane in 0..lanes {
                        st.prod[uv * TILE_LANES + lane] = st.p[row + lane * ocg];
                    }
                }
                plan.inverse_tiles8(&st.prod, &mut st.iscr, &mut st.ytile);
                for lane in 0..lanes {
                    let tile_idx = base + lane;
                    let (ty, tx) = (tile_idx / tiles_x, tile_idx % tiles_x);
                    for i in 0..m.min(oh - ty * m) {
                        for j in 0..m.min(ow - tx * m) {
                            plane[(ty * m + i) * ow + tx * m + j] =
                                ep.apply(st.ytile[(i * m + j) * TILE_LANES + lane] + b);
                        }
                    }
                }
            }
        }
    });
    for st in states {
        st.give(ws);
    }
}

/// Tiled fast convolution (stride 1), float transform domain. The group
/// count is inferred from the weight shape (`groups = IC / weight IC`).
pub fn conv2d_fast(x: &Tensor, w: &Tensor, bias: &[f32], plan: &FastConvPlan, pad: usize) -> Tensor {
    let (n, ic, h, wid) = x.dims4();
    let (oc, icg, r, _) = w.dims4();
    assert!(icg >= 1 && ic % icg == 0, "weight channels {icg} must divide input channels {ic}");
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let mut ws = Workspace::new();
    conv2d_fast_into(x, w, bias, plan, pad, ic / icg, Epilogue::None, &mut ws, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{sfc, winograd};
    use crate::util::Pcg32;

    fn rand_tensor(dims: &[usize], rng: &mut Pcg32) -> Tensor {
        let mut t = Tensor::zeros(dims);
        rng.fill_gaussian(&mut t.data, 1.0);
        t
    }

    #[test]
    fn direct_known_values() {
        // 1 image, 1 channel, 3x3 input, 2x2 kernel of ones -> sums.
        let x = Tensor::from_vec(&[1, 1, 3, 3], (1..=9).map(|v| v as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0; 4]);
        let y = conv2d_direct(&x, &w, &[], 1, 0);
        assert_eq!(y.dims, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![12.0, 16.0, 24.0, 28.0]);
    }

    #[test]
    fn direct_stride_and_pad() {
        let mut rng = Pcg32::seeded(8);
        let x = rand_tensor(&[1, 1, 5, 5], &mut rng);
        let w = rand_tensor(&[1, 1, 3, 3], &mut rng);
        let y = conv2d_direct(&x, &w, &[], 2, 1);
        assert_eq!(y.dims, vec![1, 1, 3, 3]);
        // center output (1,1) = full 3x3 window at rows 1..4
        let mut acc = 0f32;
        for ky in 0..3 {
            for kx in 0..3 {
                acc += w.data[ky * 3 + kx] * x.at4(0, 0, 1 + ky, 1 + kx);
            }
        }
        assert!((y.at4(0, 0, 1, 1) - acc).abs() < 1e-5);
    }

    #[test]
    fn fast_matches_direct_sfc() {
        let mut rng = Pcg32::seeded(21);
        for spec in [sfc(6, 6, 3), sfc(6, 7, 3), sfc(4, 4, 3)] {
            let plan = FastConvPlan::new(spec);
            let x = rand_tensor(&[2, 3, 14, 14], &mut rng);
            let w = rand_tensor(&[4, 3, 3, 3], &mut rng);
            let bias = vec![0.3, -0.1, 0.0, 0.7];
            let direct = conv2d_direct(&x, &w, &bias, 1, 1);
            let fast = conv2d_fast(&x, &w, &bias, &plan, 1);
            assert_eq!(direct.dims, fast.dims);
            let mse = direct.mse(&fast);
            assert!(mse < 1e-8, "{}: mse {mse}", plan.algo.name);
        }
    }

    #[test]
    fn fast_matches_direct_winograd() {
        let mut rng = Pcg32::seeded(22);
        let plan = FastConvPlan::new(winograd(4, 3));
        let x = rand_tensor(&[1, 2, 8, 8], &mut rng);
        let w = rand_tensor(&[3, 2, 3, 3], &mut rng);
        let direct = conv2d_direct(&x, &w, &[], 1, 1);
        let fast = conv2d_fast(&x, &w, &[], &plan, 1);
        assert!(direct.mse(&fast) < 1e-8);
    }

    #[test]
    fn fast_5x5_kernel() {
        let mut rng = Pcg32::seeded(23);
        let plan = FastConvPlan::new(sfc(6, 6, 5));
        let x = rand_tensor(&[1, 2, 12, 12], &mut rng);
        let w = rand_tensor(&[2, 2, 5, 5], &mut rng);
        let direct = conv2d_direct(&x, &w, &[], 1, 2);
        let fast = conv2d_fast(&x, &w, &[], &plan, 2);
        assert!(direct.mse(&fast) < 1e-7);
    }

    #[test]
    fn ragged_edges() {
        // Feature size not divisible by tile M: edge tiles are clipped.
        let mut rng = Pcg32::seeded(24);
        let plan = FastConvPlan::new(sfc(6, 6, 3));
        let x = rand_tensor(&[1, 1, 11, 13], &mut rng);
        let w = rand_tensor(&[1, 1, 3, 3], &mut rng);
        let direct = conv2d_direct(&x, &w, &[], 1, 1);
        let fast = conv2d_fast(&x, &w, &[], &plan, 1);
        assert!(direct.mse(&fast) < 1e-8);
    }

    #[test]
    fn grouped_direct_matches_per_group_dense() {
        let mut rng = Pcg32::seeded(26);
        let (n, ic, oc, groups) = (2usize, 6usize, 4usize, 2usize);
        let (hh, ww, r) = (9usize, 9usize, 3usize);
        let (icg, ocg) = (ic / groups, oc / groups);
        let x = rand_tensor(&[n, ic, hh, ww], &mut rng);
        let w = rand_tensor(&[oc, icg, r, r], &mut rng);
        let bias: Vec<f32> = (0..oc).map(|i| 0.05 * i as f32).collect();
        let got = conv2d_direct_grouped(&x, &w, &bias, 1, 1, groups);
        // reference: slice each group out and run the dense kernel on it
        for gi in 0..groups {
            let mut xg = Tensor::zeros(&[n, icg, hh, ww]);
            for ni in 0..n {
                for il in 0..icg {
                    xg.plane_mut(ni, il).copy_from_slice(x.plane(ni, gi * icg + il));
                }
            }
            let mut wg = Tensor::zeros(&[ocg, icg, r, r]);
            wg.data.copy_from_slice(&w.data[gi * ocg * icg * r * r..(gi + 1) * ocg * icg * r * r]);
            let bg = bias[gi * ocg..(gi + 1) * ocg].to_vec();
            let want = conv2d_direct(&xg, &wg, &bg, 1, 1);
            for ni in 0..n {
                for ol in 0..ocg {
                    assert_eq!(
                        got.plane(ni, gi * ocg + ol),
                        want.plane(ni, ol),
                        "group {gi} out-channel {ol} must be bit-identical"
                    );
                }
            }
        }
    }

    #[test]
    fn grouped_and_depthwise_fast_match_direct() {
        let mut rng = Pcg32::seeded(27);
        let plan = FastConvPlan::new(sfc(6, 6, 3));
        for (ic, oc, groups) in [(6usize, 4usize, 2usize), (5, 5, 5)] {
            let icg = ic / groups;
            let x = rand_tensor(&[2, ic, 13, 11], &mut rng);
            let w = rand_tensor(&[oc, icg, 3, 3], &mut rng);
            let direct = conv2d_direct_grouped(&x, &w, &[], 1, 1, groups);
            let fast = conv2d_fast(&x, &w, &[], &plan, 1);
            assert_eq!(direct.dims, fast.dims);
            let mse = direct.mse(&fast);
            assert!(mse < 1e-8, "groups {groups}: mse {mse}");
        }
    }

    #[test]
    fn batched_transforms_bit_identical_to_single_tile() {
        let mut rng = Pcg32::seeded(31);
        let plan = FastConvPlan::new(sfc(6, 6, 3));
        let (t, l, m) = (plan.t(), plan.l(), plan.m());
        let (tt, lw) = (t * t, TILE_LANES);
        // forward: 8 random tiles, batched vs one-at-a-time
        let mut tiles = vec![0f32; l * l * lw];
        rng.fill_gaussian(&mut tiles, 1.0);
        let mut tscr8 = vec![0f32; t * l * lw];
        let mut tv8 = vec![0f32; tt * lw];
        plan.transform_tiles8(&tiles, &mut tscr8, &mut tv8);
        let mut tile = vec![0f32; l * l];
        let mut tscr = vec![0f32; t * l];
        let mut tv = vec![0f32; tt];
        for lane in 0..lw {
            for (e, dst) in tile.iter_mut().enumerate() {
                *dst = tiles[e * lw + lane];
            }
            plan.transform_tile(&tile, &mut tscr, &mut tv);
            for (uv, &want) in tv.iter().enumerate() {
                assert_eq!(tv8[uv * lw + lane], want, "fwd lane {lane} uv {uv}");
            }
        }
        // inverse: 8 random product blocks, batched vs one-at-a-time
        let mut p8 = vec![0f32; tt * lw];
        rng.fill_gaussian(&mut p8, 1.0);
        let mut iscr8 = vec![0f32; m * t * lw];
        let mut y8 = vec![0f32; m * m * lw];
        plan.inverse_tiles8(&p8, &mut iscr8, &mut y8);
        let mut p1 = vec![0f32; tt];
        let mut iscr = vec![0f32; m * t];
        let mut y1 = vec![0f32; m * m];
        for lane in 0..lw {
            for (e, dst) in p1.iter_mut().enumerate() {
                *dst = p8[e * lw + lane];
            }
            plan.inverse_tile(&p1, &mut iscr, &mut y1);
            for (e, &want) in y1.iter().enumerate() {
                assert_eq!(y8[e * lw + lane], want, "inv lane {lane} elem {e}");
            }
        }
    }

    #[test]
    fn sfc7_tiles_28_without_remainder() {
        // The paper's SFC-6(7,3) motivation: feature maps divisible by 7.
        let mut rng = Pcg32::seeded(25);
        let plan = FastConvPlan::new(sfc(6, 7, 3));
        let x = rand_tensor(&[1, 1, 28, 28], &mut rng);
        let w = rand_tensor(&[1, 1, 3, 3], &mut rng);
        let direct = conv2d_direct(&x, &w, &[], 1, 1);
        let fast = conv2d_fast(&x, &w, &[], &plan, 1);
        assert!(direct.mse(&fast) < 1e-8);
    }
}
