//! Minimal NCHW f32 tensor.

/// A dense f32 tensor (NCHW for activations/weights).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// dimension sizes, outermost first
    pub dims: Vec<usize>,
    /// row-major values
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zeros tensor of the given shape.
    pub fn zeros(dims: &[usize]) -> Tensor {
        Tensor { dims: dims.to_vec(), data: vec![0.0; dims.iter().product()] }
    }

    /// Tensor over an existing buffer (length must match the shape).
    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), dims.iter().product::<usize>(), "shape/data mismatch");
        Tensor { dims: dims.to_vec(), data }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// NCHW accessors.
    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        let (_, cc, hh, ww) = self.dims4();
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    #[inline]
    /// Mutable NCHW accessor.
    pub fn at4_mut(&mut self, n: usize, c: usize, h: usize, w: usize) -> &mut f32 {
        let (_, cc, hh, ww) = self.dims4();
        &mut self.data[((n * cc + c) * hh + h) * ww + w]
    }

    /// The shape as (N, C, H, W); panics unless 4-D.
    pub fn dims4(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.dims.len(), 4, "expected NCHW, got {:?}", self.dims);
        (self.dims[0], self.dims[1], self.dims[2], self.dims[3])
    }

    /// One image plane (n, c) as a contiguous slice.
    pub fn plane(&self, n: usize, c: usize) -> &[f32] {
        let (_, cc, hh, ww) = self.dims4();
        let base = (n * cc + c) * hh * ww;
        &self.data[base..base + hh * ww]
    }

    /// Mutable (n, c) image plane.
    pub fn plane_mut(&mut self, n: usize, c: usize) -> &mut [f32] {
        let (_, cc, hh, ww) = self.dims4();
        let base = (n * cc + c) * hh * ww;
        &mut self.data[base..base + hh * ww]
    }

    /// Assert this tensor has exactly the given shape (executors use it
    /// to validate caller-provided output tensors).
    #[inline]
    pub fn assert_dims(&self, dims: &[usize]) {
        assert_eq!(self.dims, dims, "tensor shape mismatch: got {:?}, want {dims:?}", self.dims);
    }

    /// Largest absolute value (0 for empty tensors).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Mean squared difference against another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.dims, other.dims);
        let n = self.len().max(1) as f64;
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4, 5]);
        *t.at4_mut(1, 2, 3, 4) = 7.0;
        assert_eq!(t.at4(1, 2, 3, 4), 7.0);
        assert_eq!(t.data[t.len() - 1], 7.0);
    }

    #[test]
    fn planes_are_contiguous() {
        let mut t = Tensor::zeros(&[1, 2, 2, 2]);
        t.plane_mut(0, 1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.at4(0, 1, 1, 0), 3.0);
    }

    #[test]
    fn mse_basic() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[1, 1, 1, 2], vec![2.0, 4.0]);
        assert!((a.mse(&b) - 2.5).abs() < 1e-12);
    }
}
