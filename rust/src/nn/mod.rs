//! The CNN inference engine (the substrate for §6's PTQ experiments).
//!
//! NCHW f32 tensors, a small SSA graph IR plus the graph compiler's
//! pass pipeline ([`passes`]: epilogue fusion, dead-node elimination,
//! int8 dataflow), conv layers that execute through any of the paper's
//! algorithms (direct im2col, tiled Winograd, tiled SFC — float or
//! transform-domain-quantized per Eq. 17), the mini-ResNet-18/34/50
//! topologies matching the paper's benchmark models, the MobileNet
//! depthwise-separable topology, the VGG-16 shape catalog for the FPGA
//! study, and the build-time weight format shared with the JAX trainer.

pub mod conv;
pub mod graph;
pub mod model;
pub mod passes;
pub mod tensor;
pub mod weights;

pub use conv::{conv2d_direct, conv2d_fast, FastConvPlan};
pub use graph::{Model, Op, PrepackReport};
pub use passes::CompileReport;
pub use tensor::Tensor;
