//! The build-time weight interchange format (shared with
//! `python/compile/train.py`): little-endian, BN pre-folded.
//!
//! layout:  b"SFCW" · u32 count · count × entry
//! entry:   u16 name_len · name bytes · u8 ndim · ndim × u32 dim · f32 data

use super::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// Weight file magic (`SFCW`).
pub const MAGIC: &[u8; 4] = b"SFCW";

/// Named tensor store (the trainer's export format).
#[derive(Debug, Default)]
pub struct WeightMap {
    /// tensors by export name
    pub tensors: BTreeMap<String, Tensor>,
}

impl WeightMap {
    /// Add or replace a tensor.
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    /// Fetch a tensor and check its shape (total size must match; the
    /// trainer may export e.g. [oc] bias as [oc]).
    pub fn tensor(&self, name: &str, dims: &[usize]) -> Tensor {
        let t = self
            .tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing weight tensor {name}"));
        assert_eq!(
            t.len(),
            dims.iter().product::<usize>(),
            "{name}: stored {:?} vs requested {:?}",
            t.dims,
            dims
        );
        Tensor::from_vec(dims, t.data.clone())
    }

    /// Write the map in the SFCW binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u16).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[t.dims.len() as u8])?;
            for &d in &t.dims {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            for v in &t.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Read a map written by [`WeightMap::save`].
    pub fn load(path: &Path) -> Result<WeightMap> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not a SFCW weight file", path.display());
        }
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let count = u32::from_le_bytes(b4) as usize;
        let mut map = WeightMap::default();
        for _ in 0..count {
            let mut b2 = [0u8; 2];
            f.read_exact(&mut b2)?;
            let name_len = u16::from_le_bytes(b2) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut b1 = [0u8; 1];
            f.read_exact(&mut b1)?;
            let ndim = b1[0] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                f.read_exact(&mut b4)?;
                dims.push(u32::from_le_bytes(b4) as usize);
            }
            let n: usize = dims.iter().product();
            let mut buf = vec![0u8; 4 * n];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            map.tensors.insert(name, Tensor { dims, data });
        }
        Ok(map)
    }
}

/// Fold batch-norm (gamma, beta, mean, var) into conv weight/bias — used
/// if a checkpoint ships unfolded BN (the JAX exporter already folds).
pub fn fold_batchnorm(
    weight: &mut Tensor,
    bias: &mut [f32],
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
) {
    let oc = weight.dims[0];
    let per_oc = weight.len() / oc;
    for o in 0..oc {
        let s = gamma[o] / (var[o] + eps).sqrt();
        for v in &mut weight.data[o * per_oc..(o + 1) * per_oc] {
            *v *= s;
        }
        bias[o] = (bias[o] - mean[o]) * s + beta[o];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let mut map = WeightMap::default();
        map.insert("conv.w", Tensor::from_vec(&[2, 1, 3, 3], (0..18).map(|v| v as f32 * 0.5).collect()));
        map.insert("fc.b", Tensor::from_vec(&[4], vec![1.0, -2.0, 0.25, 9.0]));
        let p = std::env::temp_dir().join("sfc_w_test.bin");
        map.save(&p).unwrap();
        let back = WeightMap::load(&p).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors["conv.w"].data, map.tensors["conv.w"].data);
        assert_eq!(back.tensors["fc.b"].dims, vec![4]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn bn_folding_matches_explicit() {
        let mut w = Tensor::from_vec(&[1, 1, 1, 2], vec![2.0, -1.0]);
        let mut b = vec![0.5f32];
        let (gamma, beta, mean, var) = ([2.0f32], [0.1f32], [0.3f32], [4.0f32]);
        // y = gamma*(conv(x)+b - mean)/sqrt(var+eps) + beta
        let x = [1.0f32, 3.0];
        let conv = 2.0 * x[0] - 1.0 * x[1] + b[0];
        let eps = 1e-5f32;
        let want = gamma[0] * (conv - mean[0]) / (var[0] + eps).sqrt() + beta[0];
        fold_batchnorm(&mut w, &mut b, &gamma, &beta, &mean, &var, eps);
        let got = w.data[0] * x[0] + w.data[1] * x[1] + b[0];
        assert!((got - want).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "missing weight tensor")]
    fn missing_tensor_panics() {
        let map = WeightMap::default();
        map.tensor("nope", &[1]);
    }
}
