//! Model zoo: mini-ResNet-18/34/50 (the paper's benchmark topologies at
//! reduced width for the SynthImage substrate — see DESIGN.md §2) and the
//! VGG-16 layer-shape catalog used by the FPGA study (Table 3).
//!
//! Weights are loaded from the build-time trainer's export; `random`
//! builders exist for tests and benchmarks that don't need trained
//! weights.

use super::graph::{ConvParams, Model, Op};
use super::tensor::Tensor;
use super::weights::WeightMap;
use crate::engine::{default_selector, ConvDesc, ConvPlan};
use crate::util::Pcg32;
use std::sync::Arc;

/// ResNet block config: (blocks per stage, width per stage, bottleneck?).
pub struct ResNetCfg {
    /// model name (graph + weight-map prefix)
    pub name: &'static str,
    /// residual blocks per stage
    pub stages: [usize; 4],
    /// channel width per stage
    pub widths: [usize; 4],
    /// bottleneck (1-3-1) blocks instead of basic (3-3)
    pub bottleneck: bool,
}

/// The mini ResNet-18 configuration.
pub fn resnet18_cfg() -> ResNetCfg {
    ResNetCfg { name: "resnet18", stages: [2, 2, 2, 2], widths: [16, 32, 64, 128], bottleneck: false }
}

/// The mini ResNet-34 configuration.
pub fn resnet34_cfg() -> ResNetCfg {
    ResNetCfg { name: "resnet34", stages: [3, 4, 6, 3], widths: [16, 32, 64, 128], bottleneck: false }
}

/// The mini ResNet-50 (bottleneck) configuration.
pub fn resnet50_cfg() -> ResNetCfg {
    ResNetCfg { name: "resnet50", stages: [3, 4, 6, 3], widths: [16, 32, 64, 128], bottleneck: true }
}

/// Weight source: trained map or random init.
enum Source<'a> {
    Map(&'a WeightMap),
    Random(Pcg32),
}

impl Source<'_> {
    fn conv(&mut self, name: &str, oc: usize, ic: usize, r: usize) -> (Tensor, Vec<f32>) {
        match self {
            Source::Map(map) => {
                let w = map.tensor(&format!("{name}.w"), &[oc, ic, r, r]);
                let b = map.tensor(&format!("{name}.b"), &[oc]).data;
                (w, b)
            }
            Source::Random(rng) => {
                let mut w = Tensor::zeros(&[oc, ic, r, r]);
                let fan_in = (ic * r * r) as f64;
                rng.fill_gaussian(&mut w.data, (2.0 / fan_in).sqrt());
                (w, vec![0.0; oc])
            }
        }
    }

    fn linear(&mut self, name: &str, out_dim: usize, in_dim: usize) -> (Tensor, Vec<f32>) {
        match self {
            Source::Map(map) => {
                let w = map.tensor(&format!("{name}.w"), &[out_dim, in_dim]);
                let b = map.tensor(&format!("{name}.b"), &[out_dim]).data;
                (w, b)
            }
            Source::Random(rng) => {
                let mut w = Tensor::zeros(&[out_dim, in_dim]);
                rng.fill_gaussian(&mut w.data, (1.0 / in_dim as f64).sqrt());
                (w, vec![0.0; out_dim])
            }
        }
    }
}

/// Push one dense conv node ([`push_conv_grouped`] at `groups == 1`).
#[allow(clippy::too_many_arguments)]
fn push_conv(
    m: &mut Model,
    src: &mut Source,
    name: &str,
    input: usize,
    oc: usize,
    ic: usize,
    r: usize,
    stride: usize,
    pad: usize,
    hw: usize,
) -> (usize, usize) {
    push_conv_grouped(m, src, name, input, oc, ic, r, stride, pad, 1, hw)
}

/// Push one (possibly grouped) conv node: `[OC, IC/groups, R, R]`
/// weights from `src`, execution plan from the default selector over a
/// [`ConvDesc`] of the layer's geometry (spatial size tracked by the
/// topology builder). Returns (node index, output spatial).
#[allow(clippy::too_many_arguments)]
fn push_conv_grouped(
    m: &mut Model,
    src: &mut Source,
    name: &str,
    input: usize,
    oc: usize,
    ic: usize,
    r: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    hw: usize,
) -> (usize, usize) {
    push_conv_dilated(m, src, name, input, oc, ic, r, stride, pad, groups, 1, hw)
}

/// Push one (possibly grouped, possibly dilated) conv node. Dilation
/// lives only in the plan descriptor — [`ConvParams`] carries the
/// geometry the executor reads back out of the plan — and the selector
/// routes dilated layers to the engines whose `supports()` accepts
/// them (direct and im2col). Returns (node index, output spatial).
#[allow(clippy::too_many_arguments)]
fn push_conv_dilated(
    m: &mut Model,
    src: &mut Source,
    name: &str,
    input: usize,
    oc: usize,
    ic: usize,
    r: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    dilation: usize,
    hw: usize,
) -> (usize, usize) {
    let (weight, bias) = src.conv(name, oc, ic / groups, r);
    let desc = ConvDesc::builder(ic, oc)
        .hw(hw)
        .kernel(r)
        .stride(stride)
        .pad(pad)
        .groups(groups)
        .dilation(dilation)
        .build();
    let plan = default_selector()
        .plan(&desc)
        .unwrap_or_else(|_| Arc::new(ConvPlan::direct(desc)));
    let er = (r - 1) * dilation + 1;
    let out_hw = (hw + 2 * pad - er) / stride + 1;
    let node = m.push(
        Op::Conv {
            params: ConvParams { weight, bias, stride, pad },
            plan,
            packed: None,
            quantized: None,
        },
        vec![input],
        name,
    );
    (node, out_hw)
}

fn build_resnet(cfg: &ResNetCfg, mut src: Source, classes: usize) -> Model {
    let mut m = Model::new(cfg.name);
    let input = m.push(Op::Input, vec![], "input");
    // 3×3 stem (32×32 inputs — the CIFAR-style stem, like the paper's
    // ImageNet stem scaled to our substrate)
    let mut hw = 32usize;
    let mut prev_c = cfg.widths[0];
    let (stem, stem_hw) = push_conv(&mut m, &mut src, "stem", input, prev_c, 3, 3, 1, 1, hw);
    hw = stem_hw;
    let mut cur = m.push(Op::Relu, vec![stem], "stem.relu");

    for (si, (&blocks, &width)) in cfg.stages.iter().zip(&cfg.widths).enumerate() {
        for bi in 0..blocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let prefix = format!("s{si}b{bi}");
            if !cfg.bottleneck {
                // basic block: conv3-conv3 (+ 1×1 projection on reshape)
                let (c1, hw1) =
                    push_conv(&mut m, &mut src, &format!("{prefix}.conv1"), cur, width, prev_c, 3, stride, 1, hw);
                let r1 = m.push(Op::Relu, vec![c1], format!("{prefix}.relu1"));
                let (c2, hw2) =
                    push_conv(&mut m, &mut src, &format!("{prefix}.conv2"), r1, width, width, 3, 1, 1, hw1);
                let shortcut = if stride != 1 || prev_c != width {
                    push_conv(&mut m, &mut src, &format!("{prefix}.proj"), cur, width, prev_c, 1, stride, 0, hw).0
                } else {
                    cur
                };
                let add = m.push(Op::Add, vec![c2, shortcut], format!("{prefix}.add"));
                cur = m.push(Op::Relu, vec![add], format!("{prefix}.relu2"));
                hw = hw2;
            } else {
                // bottleneck: 1×1 down, 3×3, 1×1 up (expansion 2 at mini scale)
                let mid = width;
                let out_c = width * 2;
                let (c1, _) =
                    push_conv(&mut m, &mut src, &format!("{prefix}.conv1"), cur, mid, prev_c, 1, 1, 0, hw);
                let r1 = m.push(Op::Relu, vec![c1], format!("{prefix}.relu1"));
                let (c2, hw2) =
                    push_conv(&mut m, &mut src, &format!("{prefix}.conv2"), r1, mid, mid, 3, stride, 1, hw);
                let r2 = m.push(Op::Relu, vec![c2], format!("{prefix}.relu2"));
                let (c3, _) =
                    push_conv(&mut m, &mut src, &format!("{prefix}.conv3"), r2, out_c, mid, 1, 1, 0, hw2);
                let shortcut = if stride != 1 || prev_c != out_c {
                    push_conv(&mut m, &mut src, &format!("{prefix}.proj"), cur, out_c, prev_c, 1, stride, 0, hw).0
                } else {
                    cur
                };
                let add = m.push(Op::Add, vec![c3, shortcut], format!("{prefix}.add"));
                cur = m.push(Op::Relu, vec![add], format!("{prefix}.relu3"));
                prev_c = out_c;
                hw = hw2;
                continue;
            }
            prev_c = width;
        }
    }
    let gap = m.push(Op::GlobalAvgPool, vec![cur], "gap");
    let feat = if cfg.bottleneck { cfg.widths[3] * 2 } else { cfg.widths[3] };
    let (weight, bias) = src.linear("fc", classes, feat);
    m.push(Op::Linear { weight, bias }, vec![gap], "fc");
    m
}

/// Build a mini-ResNet with trained weights.
pub fn resnet_from_weights(cfg: &ResNetCfg, map: &WeightMap, classes: usize) -> Model {
    build_resnet(cfg, Source::Map(map), classes)
}

/// Build a mini-ResNet with random (He-init) weights.
pub fn resnet_random(cfg: &ResNetCfg, seed: u64, classes: usize) -> Model {
    build_resnet(cfg, Source::Random(Pcg32::seeded(seed)), classes)
}

/// MobileNet-style depthwise-separable config: a dense stem plus
/// `(out channels, stride)` per block; every block is a depthwise 3×3
/// (`groups == channels`) followed by a pointwise 1×1 — the topology
/// family where grouped convolution dominates the MAC budget.
pub struct MobileNetCfg {
    /// model name (graph + weight-map prefix)
    pub name: &'static str,
    /// stem output channels (dense 3×3 from RGB)
    pub stem: usize,
    /// per-block (pointwise output channels, depthwise stride)
    pub blocks: &'static [(usize, usize)],
}

/// The mini MobileNet used by tests/benches/serving demos (32×32
/// SynthImage substrate, like the ResNet family above).
pub fn mobilenet_cfg() -> MobileNetCfg {
    MobileNetCfg { name: "mobilenet", stem: 16, blocks: &[(32, 1), (64, 2), (128, 2)] }
}

fn build_mobilenet(cfg: &MobileNetCfg, mut src: Source, classes: usize) -> Model {
    let mut m = Model::new(cfg.name);
    let input = m.push(Op::Input, vec![], "input");
    let mut hw = 32usize;
    let (stem, stem_hw) = push_conv(&mut m, &mut src, "stem", input, cfg.stem, 3, 3, 1, 1, hw);
    hw = stem_hw;
    let mut cur = m.push(Op::Relu, vec![stem], "stem.relu");
    let mut prev_c = cfg.stem;
    for (bi, &(width, stride)) in cfg.blocks.iter().enumerate() {
        let prefix = format!("b{bi}");
        // depthwise 3×3 over each channel, then pointwise 1×1 mixing
        let (dw, dw_hw) = push_conv_grouped(
            &mut m,
            &mut src,
            &format!("{prefix}.dw"),
            cur,
            prev_c,
            prev_c,
            3,
            stride,
            1,
            prev_c,
            hw,
        );
        let rdw = m.push(Op::Relu, vec![dw], format!("{prefix}.dw.relu"));
        let pw_name = format!("{prefix}.pw");
        let (pw, pw_hw) =
            push_conv(&mut m, &mut src, &pw_name, rdw, width, prev_c, 1, 1, 0, dw_hw);
        cur = m.push(Op::Relu, vec![pw], format!("{prefix}.pw.relu"));
        prev_c = width;
        hw = pw_hw;
    }
    let gap = m.push(Op::GlobalAvgPool, vec![cur], "gap");
    let (weight, bias) = src.linear("fc", classes, prev_c);
    m.push(Op::Linear { weight, bias }, vec![gap], "fc");
    m
}

/// Build the mini MobileNet with trained weights.
pub fn mobilenet_from_weights(cfg: &MobileNetCfg, map: &WeightMap, classes: usize) -> Model {
    build_mobilenet(cfg, Source::Map(map), classes)
}

/// Build the mini MobileNet with random (He-init) weights.
pub fn mobilenet_random(cfg: &MobileNetCfg, seed: u64, classes: usize) -> Model {
    build_mobilenet(cfg, Source::Random(Pcg32::seeded(seed)), classes)
}

/// A compact dilated-context backbone (DeepLab-style): a dense 3×3
/// stem, then size-preserving 3×3 blocks at growing dilation rates, so
/// the receptive field grows exponentially while the spatial resolution
/// never drops.
pub struct DilatedNetCfg {
    /// model name (graph + weight-map prefix)
    pub name: &'static str,
    /// stem output channels (dense 3×3 from RGB, dilation 1)
    pub stem: usize,
    /// per-block (output channels, dilation rate) — 3×3 stride-1 convs
    /// with `pad = dilation·(r−1)/2` so every block is same-size
    pub blocks: &'static [(usize, usize)],
}

/// The mini dilated backbone used by tests (32×32 substrate like the
/// families above; rates 1/2/4 over three blocks).
pub fn dilatednet_cfg() -> DilatedNetCfg {
    DilatedNetCfg { name: "dilatednet", stem: 16, blocks: &[(32, 1), (32, 2), (64, 4)] }
}

fn build_dilatednet(cfg: &DilatedNetCfg, mut src: Source, classes: usize) -> Model {
    let mut m = Model::new(cfg.name);
    let input = m.push(Op::Input, vec![], "input");
    let mut hw = 32usize;
    let (stem, stem_hw) = push_conv(&mut m, &mut src, "stem", input, cfg.stem, 3, 3, 1, 1, hw);
    hw = stem_hw;
    let mut cur = m.push(Op::Relu, vec![stem], "stem.relu");
    let mut prev_c = cfg.stem;
    for (bi, &(width, dilation)) in cfg.blocks.iter().enumerate() {
        let prefix = format!("d{bi}");
        // pad = dilation·(r−1)/2 keeps 3×3 blocks size-preserving at any rate
        let pad = dilation;
        let (c, c_hw) = push_conv_dilated(
            &mut m,
            &mut src,
            &format!("{prefix}.conv"),
            cur,
            width,
            prev_c,
            3,
            1,
            pad,
            1,
            dilation,
            hw,
        );
        cur = m.push(Op::Relu, vec![c], format!("{prefix}.relu"));
        prev_c = width;
        hw = c_hw;
    }
    // dilated depthwise context layer: grouped and dilated in one node
    let (dw, dw_hw) = push_conv_dilated(
        &mut m, &mut src, "ctx.dw", cur, prev_c, prev_c, 3, 1, 2, prev_c, 2, hw,
    );
    hw = dw_hw;
    debug_assert_eq!(hw, 32, "the dilated backbone is size-preserving end to end");
    let cur = m.push(Op::Relu, vec![dw], "ctx.dw.relu");
    let gap = m.push(Op::GlobalAvgPool, vec![cur], "gap");
    let (weight, bias) = src.linear("fc", classes, prev_c);
    m.push(Op::Linear { weight, bias }, vec![gap], "fc");
    m
}

/// Build the mini dilated backbone with trained weights.
pub fn dilatednet_from_weights(cfg: &DilatedNetCfg, map: &WeightMap, classes: usize) -> Model {
    build_dilatednet(cfg, Source::Map(map), classes)
}

/// Build the mini dilated backbone with random (He-init) weights.
pub fn dilatednet_random(cfg: &DilatedNetCfg, seed: u64, classes: usize) -> Model {
    build_dilatednet(cfg, Source::Random(Pcg32::seeded(seed)), classes)
}

/// A conv layer shape (for analytical models: BOPs, FPGA).
#[derive(Clone, Copy, Debug)]
pub struct ConvShape {
    /// input channels
    pub ic: usize,
    /// output channels
    pub oc: usize,
    /// input height
    pub h: usize,
    /// input width
    pub w: usize,
    /// square kernel size
    pub r: usize,
    /// spatial stride
    pub stride: usize,
}

impl ConvShape {
    /// MACs for direct execution.
    pub fn direct_macs(&self) -> u64 {
        let oh = (self.h / self.stride) as u64;
        let ow = (self.w / self.stride) as u64;
        oh * ow * self.oc as u64 * self.ic as u64 * (self.r * self.r) as u64
    }
}

/// The real VGG-16 conv stack (224×224 input) — every layer 3×3 stride 1,
/// which is why the paper uses it for the FPGA study.
pub fn vgg16_conv_shapes() -> Vec<ConvShape> {
    let cfg: [(usize, usize, usize); 13] = [
        (3, 64, 224),
        (64, 64, 224),
        (64, 128, 112),
        (128, 128, 112),
        (128, 256, 56),
        (256, 256, 56),
        (256, 256, 56),
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 28),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
    ];
    cfg.iter()
        .map(|&(ic, oc, s)| ConvShape { ic, oc, h: s, w: s, r: 3, stride: 1 })
        .collect()
}

/// Conv shapes of a built model (for the analytical cost models), taking
/// the activation sizes from a forward pass on one dummy image.
pub fn model_conv_shapes(model: &Model, input_hw: usize) -> Vec<(String, ConvShape)> {
    let x = Tensor::zeros(&[1, 3, input_hw, input_hw]);
    let acts = model.forward_all(&x);
    model
        .nodes
        .iter()
        .enumerate()
        .filter_map(|(i, n)| match &n.op {
            Op::Conv { params, .. } => {
                let (_, ic, h, w) = acts[model.nodes[i].inputs[0]].dims4();
                Some((
                    n.name.clone(),
                    ConvShape {
                        ic,
                        oc: params.weight.dims[0],
                        h,
                        w,
                        r: params.weight.dims[2],
                        stride: params.stride,
                    },
                ))
            }
            _ => None,
        })
        .collect()
}

/// Conv descriptors of a built model, read straight from each conv
/// node's engine plan — preserving stride/pad **and groups/dilation**,
/// which the dense [`ConvShape`] view cannot carry — with the batch size
/// overridden and any quantization scheme stripped (callers re-attach
/// their own). This is what `sfc autotune` iterates.
pub fn model_conv_descs(model: &Model, batch: usize) -> Vec<(String, ConvDesc)> {
    model
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            Op::Conv { plan, .. } => {
                let mut d = plan.desc;
                d.batch = batch;
                d.quant = None;
                Some((n.name.clone(), d))
            }
            _ => None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_forward_shape() {
        let m = resnet_random(&resnet18_cfg(), 1, 10);
        let x = Tensor::zeros(&[2, 3, 32, 32]);
        let y = m.forward(&x);
        assert_eq!(y.dims, vec![2, 10, 1, 1]);
    }

    #[test]
    fn resnet50_bottleneck_forward() {
        let m = resnet_random(&resnet50_cfg(), 2, 10);
        let x = Tensor::zeros(&[1, 3, 32, 32]);
        let y = m.forward(&x);
        assert_eq!(y.dims, vec![1, 10, 1, 1]);
    }

    #[test]
    fn conv_counts_match_topology() {
        // resnet18: stem + 2 convs × 8 blocks + 3 projections = 20.
        let m = resnet_random(&resnet18_cfg(), 3, 10);
        assert_eq!(m.conv_nodes().len(), 20);
        // resnet34: stem + 2×16 + 3 proj = 36
        let m = resnet_random(&resnet34_cfg(), 3, 10);
        assert_eq!(m.conv_nodes().len(), 36);
        // resnet50: stem + 3×16 + 4 proj = 53
        let m = resnet_random(&resnet50_cfg(), 3, 10);
        assert_eq!(m.conv_nodes().len(), 53);
    }

    #[test]
    fn vgg16_has_13_convs() {
        let shapes = vgg16_conv_shapes();
        assert_eq!(shapes.len(), 13);
        let total: u64 = shapes.iter().map(|s| s.direct_macs()).sum();
        // VGG-16 conv MACs ≈ 15.3 G
        assert!((total as f64 - 15.3e9).abs() / 15.3e9 < 0.03, "total {total}");
    }

    #[test]
    fn shapes_probe() {
        let m = resnet_random(&resnet18_cfg(), 4, 10);
        let shapes = model_conv_shapes(&m, 32);
        assert_eq!(shapes.len(), 20);
        assert_eq!(shapes[0].1.ic, 3);
        assert_eq!(shapes[0].1.h, 32);
    }

    #[test]
    fn mobilenet_depthwise_forward_shape() {
        let cfg = mobilenet_cfg();
        let m = mobilenet_random(&cfg, 5, 10);
        let x = Tensor::zeros(&[2, 3, 32, 32]);
        let y = m.forward(&x);
        assert_eq!(y.dims, vec![2, 10, 1, 1]);
        // stem + (dw + pw) per block
        assert_eq!(m.conv_nodes().len(), 1 + 2 * cfg.blocks.len());
    }

    #[test]
    fn dilated_backbone_forward_ws_end_to_end() {
        use crate::engine::Workspace;
        use crate::util::Pcg32;
        let cfg = dilatednet_cfg();
        let m = dilatednet_random(&cfg, 7, 10);
        // stem + one conv per block + the depthwise context layer
        assert_eq!(m.conv_nodes().len(), 1 + cfg.blocks.len() + 1);
        let descs = model_conv_descs(&m, 2);
        let rates: Vec<usize> =
            descs.iter().filter(|(n, _)| n.ends_with(".conv")).map(|(_, d)| d.dilation).collect();
        assert_eq!(rates, vec![1, 2, 4], "block dilation schedule survives into the plans");
        let ctx = descs.iter().find(|(n, _)| n == "ctx.dw").unwrap();
        assert_eq!((ctx.1.groups, ctx.1.dilation), (ctx.1.ic, 2), "grouped + dilated node");
        let mut x = Tensor::zeros(&[2, 3, 32, 32]);
        Pcg32::seeded(0xD1A).fill_gaussian(&mut x.data, 1.0);
        let want = m.forward(&x);
        assert_eq!(want.dims, vec![2, 10, 1, 1]);
        let mut ws = Workspace::new();
        let y = m.forward_ws(&x, &mut ws);
        assert_eq!(y.data, want.data, "workspace forward is bit-identical");
        let warm = ws.heap_allocs();
        let y2 = m.forward_ws(&x, &mut ws);
        assert_eq!(y2.data, want.data);
        assert_eq!(ws.heap_allocs(), warm, "steady-state dilated forward allocates");
    }

    #[test]
    fn mobilenet_descs_carry_depthwise_groups() {
        let cfg = mobilenet_cfg();
        let m = mobilenet_random(&cfg, 6, 10);
        let descs = model_conv_descs(&m, 4);
        let dw: Vec<_> = descs.iter().filter(|(n, _)| n.ends_with(".dw")).collect();
        assert_eq!(dw.len(), cfg.blocks.len());
        for (name, d) in dw {
            assert_eq!(d.groups, d.ic, "{name} must be depthwise");
            assert_eq!(d.batch, 4);
        }
        let pw: Vec<_> = descs.iter().filter(|(n, _)| n.ends_with(".pw")).collect();
        assert!(pw.iter().all(|(_, d)| d.groups == 1 && d.r == 1));
    }
}
