//! Bench: the conv engine hot path through the unified `ConvEngine` API —
//! every catalog engine on ResNet/VGG-scale layer shapes, float and
//! transform-domain-quantized (Eq. 17), plus the heuristic selector's
//! pick and the plan-cache counters. This is the L3 §Perf workload of
//! EXPERIMENTS.md. `cargo bench --bench conv_engine`.

use sfc::engine::{default_selector, ConvDesc, QuantSpec};
use sfc::nn::Tensor;
use sfc::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use sfc::util::timer::bench;
use sfc::util::Pcg32;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn main() {
    let mut rng = Pcg32::seeded(42);
    // Layer shapes: SynthImage-scale and VGG-scale.
    let cases = [
        ("28x28x32->32", [1usize, 32, 28, 28], [32usize, 32, 3, 3]),
        ("14x14x128->128", [1, 128, 14, 14], [128, 128, 3, 3]),
        ("56x56x64->64", [1, 64, 56, 56], [64, 64, 3, 3]),
    ];
    let sel = default_selector();
    for (label, xd, wd) in cases {
        let x = rand_tensor(&xd, &mut rng, 1.0);
        let w = rand_tensor(&wd, &mut rng, 0.2);
        let macs = (xd[2] * xd[3] * wd[0] * wd[1] * 9) as f64;
        let desc = ConvDesc::new(1, wd[1], wd[0], xd[2], xd[3], 3, 1, 1);

        println!("\n=== layer {label} ({:.1} MMACs) ===", macs / 1e6);
        let direct_plan = sel.plan_named("direct", &desc).unwrap();
        let s_direct =
            bench(&format!("{label} direct"), 2, 5, 0.6, || direct_plan.run(&x, &w, &[]));

        for name in ["im2col-gemm", "SFC-6(7x7,3x3)", "SFC-6(6x6,3x3)", "Wino(4x4,3x3)", "FFT", "NTT"] {
            let Ok(plan) = sel.plan_named(name, &desc) else {
                println!("{label} {name:<18} (unsupported at this shape)");
                continue;
            };
            let s = bench(&format!("{label} {name} f32"), 2, 5, 0.6, || plan.run(&x, &w, &[]));
            println!("    -> {:.2}x vs direct", s_direct.median_s / s.median_s);
        }

        let hplan = sel.plan(&desc).unwrap();
        println!("  heuristic selector picks: {}", hplan.engine);

        // quantized SFC path (int8 transform domain) through the same API
        let spec = QuantSpec::transform_default(8);
        let qdesc = desc.with_quant(spec);
        let qplan = sel.plan_named("SFC-6(7x7,3x3)", &qdesc).unwrap();
        let maxima = collect_act_maxima(&x, qplan.fast_plan().unwrap(), 1);
        let q = QConvLayer::from_plan(qplan, &w, vec![], &QCalib::TransformMaxima(&maxima));
        let s = bench(&format!("{label} SFC-6(7x7,3x3) int8"), 2, 5, 0.6, || q.forward(&x));
        println!("    -> {:.2}x vs direct f32", s_direct.median_s / s.median_s);
    }

    let (hits, misses) = sfc::coordinator::metrics::plan_cache_counters();
    println!("\nplan cache: {hits} hits / {misses} misses");
}
