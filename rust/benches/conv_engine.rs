//! Bench: the conv engine hot path — direct vs tiled Winograd vs tiled
//! SFC, float and transform-domain-quantized (Eq. 17), on ResNet-scale
//! layer shapes. This is the L3 §Perf workload of EXPERIMENTS.md.
//! `cargo bench --bench conv_engine`.

use std::sync::Arc;

use sfc::algo::{sfc, winograd};
use sfc::nn::conv::{conv2d_direct, conv2d_fast, FastConvPlan};
use sfc::nn::Tensor;
use sfc::quant::qconv::{collect_act_maxima, Granularity, QConvLayer};
use sfc::util::timer::bench;
use sfc::util::Pcg32;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn main() {
    let mut rng = Pcg32::seeded(42);
    // Layer shapes: SynthImage-scale and VGG-scale.
    let cases = [
        ("28x28x32->32", [1usize, 32, 28, 28], [32usize, 32, 3, 3]),
        ("14x14x128->128", [1, 128, 14, 14], [128, 128, 3, 3]),
        ("56x56x64->64", [1, 64, 56, 56], [64, 64, 3, 3]),
    ];
    for (label, xd, wd) in cases {
        let x = rand_tensor(&xd, &mut rng, 1.0);
        let w = rand_tensor(&wd, &mut rng, 0.2);
        let macs = (xd[2] * xd[3] * wd[0] * wd[1] * 9) as f64;

        println!("\n=== layer {label} ({:.1} MMACs) ===", macs / 1e6);
        let s_direct = bench(&format!("{label} direct"), 2, 5, 0.6, || {
            conv2d_direct(&x, &w, &[], 1, 1)
        });

        for (name, algo) in [
            ("SFC-6(7,3)", sfc(6, 7, 3)),
            ("SFC-6(6,3)", sfc(6, 6, 3)),
            ("Wino(4,3)", winograd(4, 3)),
        ] {
            let plan = FastConvPlan::new(algo);
            let s = bench(&format!("{label} {name} f32"), 2, 5, 0.6, || {
                conv2d_fast(&x, &w, &[], &plan, 1)
            });
            println!("    -> {:.2}x vs direct", s_direct.median_s / s.median_s);
        }

        // quantized SFC path (int8 transform domain)
        let plan = Arc::new(FastConvPlan::new(sfc(6, 7, 3)));
        let maxima = collect_act_maxima(&x, &plan, 1);
        let q = QConvLayer::fast(
            plan,
            &w,
            vec![],
            1,
            8,
            8,
            Granularity::ChannelFreq,
            Granularity::Freq,
            &maxima,
        );
        let s = bench(&format!("{label} SFC-6(7,3) int8"), 2, 5, 0.6, || q.forward(&x));
        println!("    -> {:.2}x vs direct f32", s_direct.median_s / s.median_s);
    }
}
