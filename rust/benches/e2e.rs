//! Bench: end-to-end serving throughput/latency over the PJRT artifacts
//! (direct vs Pallas-SFC model variants, batch 1 vs 8). Skips gracefully
//! when `make artifacts` has not been run. `cargo bench --bench e2e`.

use sfc::coordinator::{LatencyStats, Server, ServerConfig};
use sfc::exp;
use sfc::runtime::Executor;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let data_dir = "artifacts";
    if !PathBuf::from(data_dir).join("dataset_test.bin").exists() {
        println!("(skipping e2e bench: run `make artifacts` first)");
        return Ok(());
    }
    let (images, labels) = exp::load_split(data_dir, "test", 64)?;
    let sample = 3 * 32 * 32;
    for variant in ["resnet18", "resnet18_sfc"] {
        for batch in [1usize, 8] {
            let hlo = PathBuf::from(format!("{data_dir}/{variant}_b{batch}.hlo.txt"));
            if !hlo.exists() {
                println!("(skipping {variant} b{batch}: artifact missing)");
                continue;
            }
            let dims = vec![batch, 3, 32, 32];
            let hlo2 = hlo.clone();
            let server = Server::start(
                move || Executor::load(&hlo2, &dims, 10),
                ServerConfig { batch_size: batch, queue_depth: 64, batch_timeout_ms: 2 },
            )?;
            let n = labels.len();
            let t0 = std::time::Instant::now();
            let handles: Vec<_> = (0..n)
                .map(|i| server.submit(images.data[i * sample..(i + 1) * sample].to_vec()).unwrap())
                .collect();
            let mut lats = Vec::new();
            let mut correct = 0;
            for (i, h) in handles.into_iter().enumerate() {
                let r = h.wait()?;
                lats.push(r.latency_s);
                correct += (r.argmax == labels[i] as usize) as usize;
            }
            let wall = t0.elapsed().as_secs_f64();
            let s = LatencyStats::from_samples(&lats);
            println!(
                "{variant:<14} batch {batch}: {:>7.1} img/s · p50 {:>7.2} ms · p95 {:>7.2} ms · acc {:.1}%",
                n as f64 / wall,
                s.p50 * 1e3,
                s.p95 * 1e3,
                100.0 * correct as f64 / n as f64
            );
            server.shutdown();
        }
    }
    Ok(())
}
