//! Bench: regenerate Table 3 (FPGA accelerator comparison) and time the
//! cycle-level pipeline simulator. `cargo bench --bench fpga`.

use sfc::algo::{sfc, winograd};
use sfc::fpga::{evaluate, pipeline::simulate, Accel};
use sfc::nn::model::vgg16_conv_shapes;
use sfc::util::timer::bench;

fn main() {
    let shapes = vgg16_conv_shapes();
    println!("=== Table 3 regeneration (VGG-16 @ 200 MHz, simulated) ===");
    let rows = [
        (evaluate(&Accel::from_bilinear("Winograd", &winograd(4, 3), 4, 4, 16), &shapes, "16bit"), 5.64),
        (evaluate(&Accel::ntt("NTT", 8, 3, 4, 4, 21), &shapes, "8/21bit"), 3.48),
        (evaluate(&Accel::direct("direct", 7, 3, 4, 4, 8), &shapes, "8bit"), 1.96),
        (evaluate(&Accel::from_bilinear("SFC", &sfc(6, 7, 3), 4, 4, 8), &shapes, "8bit"), 10.08),
    ];
    println!(
        "{:<10} {:>9} {:>8} {:>7} {:>9} {:>14} {:>8}",
        "Design", "Precision", "LUTs(K)", "DSPs", "GOPs", "GOPs/DSP/GHz", "(paper)"
    );
    for (r, paper) in rows {
        println!(
            "{:<10} {:>9} {:>8.0} {:>7} {:>9.0} {:>14.2} {:>8.2}",
            r.name, r.precision, r.luts_k, r.dsps, r.gops, r.gops_per_dsp_per_clock, paper
        );
    }

    println!("\n=== simulator timing ===");
    let acc = Accel::from_bilinear("SFC", &sfc(6, 7, 3), 4, 4, 8);
    bench("vgg16_pipeline_sim", 3, 50, 1.0, || simulate(&acc, &shapes));
}
