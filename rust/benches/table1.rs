//! Bench: regenerate Table 1 (numerical error / κ / complexity) and time
//! the error-measurement harness. `cargo bench --bench table1`.

use sfc::error::{table1, OdotFormat};
use sfc::util::timer::bench;

fn main() {
    println!("=== Table 1 regeneration (fp16 ⊙, 2000 trials) ===");
    let rows = table1(OdotFormat::Fp16, 2000);
    println!("{:<20} {:>10} {:>8} {:>12}", "Algorithm", "MSE(rel)", "κ(Aᵀ)", "Complexity");
    for r in &rows {
        println!("{:<20} {:>10.2} {:>8.1} {:>11.2}%", r.name, r.mse, r.kappa, r.complexity * 100.0);
    }

    println!("\n=== Table 1 under int8 ⊙ (the PTQ regime) ===");
    for r in table1(OdotFormat::Int(8), 1000) {
        println!("{:<20} {:>10.2}", r.name, r.mse);
    }

    println!("\n=== harness timing ===");
    bench("table1_fp16_100trials", 1, 5, 1.0, || table1(OdotFormat::Fp16, 100));
}
