#!/usr/bin/env python3
"""Perf-regression gate over `sfc bench --json` / `sfc loadgen --json` snapshots.

Compares a freshly measured BENCH_conv.json against the committed
baseline snapshot and fails CI on hard ns/call regressions on the gated
rows:

  * the dense 3x3 shapes (shape labels containing "->": the GEMM-backed
    conv hot path), and
  * the compiled-MobileNet end-to-end rows (shape "mobilenet-*",
    engines "e2e-f32-compiled" / "e2e-int8-compiled").

Policy (ratios of fresh/baseline median ns/call, matched by
(shape, engine)):

  * ratio >  1 + --fail-pct/100  (default 15%)  -> hard failure, exit 1
  * ratio in (1 + --warn-pct/100, 1 + --fail-pct/100]  (5..15%)
                                                 -> GitHub warning only
  * gated row present in the baseline but missing from the fresh run
                                                 -> hard failure (a row
                                                   silently disappearing
                                                   is itself a regression)

Bootstrap mode: when the baseline file does not exist yet, the gate
prints a warning and exits 0 -- the CI job uploads the fresh snapshot as
an artifact so a maintainer can commit it as the first baseline. The
gate never writes or synthesizes baseline numbers itself; baselines only
ever come from a real measured run.

Comparability guards: the gate refuses to compare (warns, exits 0)
when the kernel dispatch arms differ (scalar vs avx2 timings are not
comparable) and tolerates schema drift as long as both files carry the
gated rows.

Serving snapshots (`sfc loadgen --json --out BENCH_serve.json`,
`bench: "serve"`) gate per-model records instead of per-shape rows:

  * goodput and deadline_met_ratio must not drop more than --fail-pct
    below the baseline (these are higher-is-better),
  * p99_ms must not rise more than --fail-pct above the baseline,
  * a model present in the baseline but missing from the fresh run is a
    hard failure.

The same bootstrap mode applies (no committed BENCH_serve baseline ->
warn and exit 0), plus one extra comparability guard: snapshots from
different --sched dispatch arms are never compared.
"""

import argparse
import json
import sys


def is_gated(row):
    shape = row.get("shape", "")
    engine = row.get("engine", "")
    if "->" in shape and not engine.startswith("e2e-"):
        return True  # dense 3x3 conv rows
    return shape.startswith("mobilenet-") and engine.startswith("e2e-")


def load(path):
    with open(path) as f:
        d = json.load(f)
    if d.get("bench") == "conv" and "results" in d:
        return d
    if d.get("bench") == "serve" and "models" in d:
        return d
    sys.exit(f"bench_gate: {path} is not a BENCH_conv or BENCH_serve snapshot")


def gate_serve(base, fresh, args):
    """Gate a serve snapshot: per-model goodput / deadline_met_ratio /
    p99_ms against the baseline. Returns the process exit code."""
    bs, fs = base.get("sched"), fresh.get("sched")
    if bs != fs:
        print(
            f"::warning::bench_gate: sched arm mismatch (baseline={bs}, fresh={fs}) -- "
            "dispatch policies are not comparable, skipping the gate"
        )
        return 0
    base_models = {m["model"]: m for m in base["models"]}
    fresh_models = {m["model"]: m for m in fresh["models"]}
    if not base_models:
        sys.exit("bench_gate: serve baseline contains no models -- was it a real run?")

    fail_at = args.fail_pct / 100.0
    warn_at = args.warn_pct / 100.0
    failures = []
    for name in sorted(base_models):
        if name not in fresh_models:
            failures.append(f"{name}: model missing from the fresh snapshot")
            continue
        b, f = base_models[name], fresh_models[name]
        # higher-is-better metrics: fail when fresh drops too far below
        for metric in ("goodput", "deadline_met_ratio"):
            bv, fv = b.get(metric, 0), f.get(metric, 0)
            if bv <= 0:
                print(f"bench_gate: {name}/{metric} baseline is {bv}, skipping")
                continue
            drop = (bv - fv) / bv
            if drop > fail_at:
                failures.append(f"{name}/{metric}: {bv} -> {fv} (-{drop * 100.0:.1f}%)")
            elif drop > warn_at:
                print(
                    f"::warning::bench_gate: {name}/{metric} dropped "
                    f"{bv} -> {fv} (-{drop * 100.0:.1f}%)"
                )
            else:
                print(f"bench_gate ok: {name}/{metric} {bv} -> {fv}")
        # lower-is-better latency: fail when fresh rises too far above
        bv, fv = b.get("p99_ms", 0), f.get("p99_ms", 0)
        if bv > 0:
            rise = (fv - bv) / bv
            if rise > fail_at:
                failures.append(f"{name}/p99_ms: {bv:.2f} -> {fv:.2f} ms (+{rise * 100.0:.1f}%)")
            elif rise > warn_at:
                print(
                    f"::warning::bench_gate: {name}/p99_ms rose "
                    f"{bv:.2f} -> {fv:.2f} ms (+{rise * 100.0:.1f}%)"
                )
            else:
                print(f"bench_gate ok: {name}/p99_ms {bv:.2f} -> {fv:.2f} ms")

    for name in sorted(set(fresh_models) - set(base_models)):
        print(f"bench_gate: new model (no baseline yet): {name}")

    if failures:
        for line in failures:
            print(f"::error::bench_gate serving regression: {line}")
        return 1
    print(f"bench_gate: {len(base_models)} serving models within thresholds of baseline")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed snapshot (e.g. BENCH_conv.json)")
    ap.add_argument("--fresh", required=True, help="snapshot measured by this CI run")
    ap.add_argument("--fail-pct", type=float, default=15.0, help="hard-failure threshold (%%)")
    ap.add_argument("--warn-pct", type=float, default=5.0, help="soft-warning threshold (%%)")
    args = ap.parse_args()

    try:
        base = load(args.baseline)
    except FileNotFoundError:
        print(
            f"::warning::bench_gate: no committed baseline at {args.baseline} -- "
            "bootstrap mode. Commit the artifact uploaded by this job as the "
            "first baseline to arm the gate."
        )
        return 0
    fresh = load(args.fresh)

    if base.get("bench") != fresh.get("bench"):
        sys.exit(
            f"bench_gate: snapshot kind mismatch (baseline={base.get('bench')}, "
            f"fresh={fresh.get('bench')}) -- compare conv to conv, serve to serve"
        )

    bk, fk = base.get("kernel"), fresh.get("kernel")
    if bk != fk:
        print(
            f"::warning::bench_gate: kernel arm mismatch (baseline={bk}, fresh={fk}) -- "
            "timings are not comparable on this runner, skipping the gate"
        )
        return 0

    if base.get("bench") == "serve":
        return gate_serve(base, fresh, args)

    base_rows = {(r["shape"], r["engine"]): r for r in base["results"] if is_gated(r)}
    fresh_rows = {(r["shape"], r["engine"]): r for r in fresh["results"] if is_gated(r)}
    if not base_rows:
        sys.exit("bench_gate: baseline contains no gated rows -- was it a real `sfc bench --json` run?")

    fail_at = 1.0 + args.fail_pct / 100.0
    warn_at = 1.0 + args.warn_pct / 100.0
    failures = []
    for key in sorted(base_rows):
        shape, engine = key
        tag = f"{engine} @ {shape}"
        if key not in fresh_rows:
            failures.append(f"{tag}: gated row missing from the fresh snapshot")
            continue
        b = base_rows[key]["ns_per_call"]
        f = fresh_rows[key]["ns_per_call"]
        if b <= 0:
            failures.append(f"{tag}: baseline ns_per_call is {b}")
            continue
        ratio = f / b
        pct = (ratio - 1.0) * 100.0
        if ratio > fail_at:
            failures.append(f"{tag}: {b:.0f} -> {f:.0f} ns/call (+{pct:.1f}%)")
        elif ratio > warn_at:
            print(f"::warning::bench_gate: {tag} slowed {b:.0f} -> {f:.0f} ns/call (+{pct:.1f}%)")
        else:
            print(f"bench_gate ok: {tag} {b:.0f} -> {f:.0f} ns/call ({pct:+.1f}%)")

    extra = sorted(set(fresh_rows) - set(base_rows))
    for shape, engine in extra:
        print(f"bench_gate: new gated row (no baseline yet): {engine} @ {shape}")

    if failures:
        for line in failures:
            print(f"::error::bench_gate regression: {line}")
        return 1
    print(f"bench_gate: {len(base_rows)} gated rows within +{args.fail_pct:.0f}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
