"""Load the exact SFC/Winograd transformation matrices.

The Rust constructor (`rust/src/algo/`) is the single source of truth: it
derives every (G, Bᵀ, Aᵀ) triple from the symbolic-DFT construction with
exact rational arithmetic and `sfc dump-algos` exports them as text into
``artifacts/algos/``. This module parses those files so the JAX/Pallas
layer is guaranteed bit-identical to the Rust engine.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from fractions import Fraction

import numpy as np

ALGOS_DIR = os.environ.get(
    "SFC_ALGOS_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "algos"),
)


@dataclass
class Bilinear:
    """A 1-D bilinear convolution algorithm z = Aᵀ((G·f) ⊙ (Bᵀ·x))."""

    name: str
    m: int  # output tile
    r: int  # kernel taps
    t: int  # multiplications
    l: int  # input tile (m + r - 1)
    bt: np.ndarray  # T×L float64
    g: np.ndarray  # T×R
    at: np.ndarray  # M×T

    def mults_2d(self) -> int:
        return self.t * self.t


def _parse_matrix(lines, idx):
    header = lines[idx].split()
    rows, cols = int(header[1]), int(header[2])
    data = np.zeros((rows, cols), dtype=np.float64)
    for i in range(rows):
        vals = lines[idx + 1 + i].split()
        assert len(vals) == cols
        for j, v in enumerate(vals):
            data[i, j] = float(Fraction(v))
    return data, idx + 1 + rows


def load(name: str) -> Bilinear:
    """Load by file stem, e.g. ``sfc-6_7x7_3x3_`` or a friendly alias like
    ``SFC-6(7x7,3x3)``."""
    stem = name.lower().replace("(", "_").replace(")", "_").replace(",", "_")
    path = os.path.join(ALGOS_DIR, f"{stem}.txt")
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"{path} — run `cargo run --release -- dump-algos` (or `make artifacts`)"
        )
    with open(path) as f:
        lines = [ln.rstrip("\n") for ln in f]
    meta = {}
    idx = 0
    while idx < len(lines) and not lines[idx].startswith(("BT", "G ", "AT")):
        k, v = lines[idx].split(maxsplit=1)
        meta[k] = v
        idx += 1
    bt, idx = _parse_matrix(lines, idx)
    g, idx = _parse_matrix(lines, idx)
    at, idx = _parse_matrix(lines, idx)
    return Bilinear(
        name=meta["name"],
        m=int(meta["m"]),
        r=int(meta["r"]),
        t=int(meta["t"]),
        l=int(meta["l"]),
        bt=bt,
        g=g,
        at=at,
    )


def sfc_7x7_3x3() -> Bilinear:
    """The paper's flagship algorithm (SFC-6(7×7, 3×3))."""
    return load("sfc-6_7x7_3x3_")


def sfc_6x6_3x3() -> Bilinear:
    return load("sfc-6_6x6_3x3_")


def sfc_4x4_3x3() -> Bilinear:
    return load("sfc-4_4x4_3x3_")


def wino_4x4_3x3() -> Bilinear:
    return load("wino_4x4_3x3_")
