"""Build-time trainer: mini-ResNets on SynthImage, exported as SFCW
weights for the Rust engine (and reused by aot.py).

Runs once under `make artifacts`; Python never executes at serving time.
Adam is implemented inline (optax is not in this image).

Usage: python -m compile.train --model resnet18 --steps 400 --out ../artifacts
"""

from __future__ import annotations

import argparse
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model


def save_weights(params: dict, path: str) -> None:
    """SFCW format (see rust/src/nn/weights.rs)."""
    with open(path, "wb") as f:
        f.write(b"SFCW")
        f.write(struct.pack("<I", len(params)))
        for name in sorted(params):
            arr = np.asarray(params[name], dtype=np.float32)
            f.write(struct.pack("<H", len(name)))
            f.write(name.encode())
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros(())}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="resnet18", choices=list(model.CONFIGS))
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_dir = args.out or args.data_dir

    train = dataset.load(os.path.join(args.data_dir, "dataset_train.bin"))
    test = dataset.load(os.path.join(args.data_dir, "dataset_test.bin"))
    print(f"train {train.images.shape}, test {test.images.shape}")

    params = model.init_params(args.model, jax.random.PRNGKey(args.seed))

    def loss_fn(params, x, y):
        logits = model.forward(params, x, args.model)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(x.shape[0]), y])

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, state = adam_step(params, grads, state, lr=args.lr)
        return params, state, loss

    @jax.jit
    def eval_logits(params, x):
        return model.forward(params, x, args.model)

    def accuracy(params, images, labels, bs=200):
        correct = 0
        for i in range(0, len(labels), bs):
            logits = eval_logits(params, jnp.asarray(images[i : i + bs]))
            correct += int((np.argmax(np.asarray(logits), axis=1) == labels[i : i + bs]).sum())
        return correct / len(labels)

    rng = np.random.default_rng(args.seed)
    state = adam_init(params)
    n = train.images.shape[0]
    t0 = time.time()
    loss_log = []
    for s in range(args.steps):
        idx = rng.integers(0, n, size=args.batch)
        x = jnp.asarray(train.images[idx])
        y = jnp.asarray(train.labels[idx].astype(np.int32))
        params, state, loss = step(params, state, x, y)
        loss_log.append(float(loss))
        if s % 50 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)", flush=True)

    train_acc = accuracy(params, train.images[:1000], train.labels[:1000])
    test_acc = accuracy(params, test.images, test.labels)
    print(f"{args.model}: train acc {train_acc:.4f}, TEST acc {test_acc:.4f}")

    os.makedirs(out_dir, exist_ok=True)
    wpath = os.path.join(out_dir, f"{args.model}.w32")
    save_weights(params, wpath)
    print(f"wrote {wpath}")
    # loss curve for EXPERIMENTS.md
    with open(os.path.join(out_dir, f"{args.model}_loss.txt"), "w") as f:
        f.write(f"# {args.model} steps={args.steps} batch={args.batch} lr={args.lr}\n")
        f.write(f"# final train_acc={train_acc:.4f} test_acc={test_acc:.4f}\n")
        for i, l in enumerate(loss_log):
            f.write(f"{i} {l:.5f}\n")


if __name__ == "__main__":
    main()
