"""Layer-2: the mini-ResNet family in JAX.

The topology, parameter naming and initialization mirror
`rust/src/nn/model.rs` exactly (stem → 4 stages → GAP → FC, widths
16/32/64/128, basic blocks for 18/34 and 2×-expansion bottlenecks for 50)
so that weights exported by `train.py` load directly into the Rust
engine. No batch norm — biases only (DESIGN.md §2).

`forward` takes a `conv_impl` so the same graph runs with XLA's native
convolution (training, the `direct` AOT artifact) or the Pallas SFC
kernel (the `sfc` artifact that proves L1⊂L2⊂L3 composition).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.ref import conv2d_ref

CONFIGS = {
    "resnet18": dict(stages=[2, 2, 2, 2], widths=[16, 32, 64, 128], bottleneck=False),
    "resnet34": dict(stages=[3, 4, 6, 3], widths=[16, 32, 64, 128], bottleneck=False),
    "resnet50": dict(stages=[3, 4, 6, 3], widths=[16, 32, 64, 128], bottleneck=True),
}


def init_params(name: str, key, classes: int = 10) -> dict:
    cfg = CONFIGS[name]
    params = {}

    def conv(pname, oc, ic, r, key):
        k1, key = jax.random.split(key)
        fan_in = ic * r * r
        params[f"{pname}.w"] = jax.random.normal(k1, (oc, ic, r, r), jnp.float32) * np.sqrt(
            2.0 / fan_in
        )
        params[f"{pname}.b"] = jnp.zeros((oc,), jnp.float32)
        return key

    key = conv("stem", cfg["widths"][0], 3, 3, key)
    # Fixup-style residual scaling: without batch norm, deep residual
    # stacks explode at init unless each block's final conv is downscaled
    # by ~L^(-1/2) (Zhang et al., 2019). Keeps resnet34/50 trainable.
    n_blocks = sum(cfg["stages"])
    fixup = n_blocks ** -0.5
    prev_c = cfg["widths"][0]
    for si, (blocks, width) in enumerate(zip(cfg["stages"], cfg["widths"])):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            p = f"s{si}b{bi}"
            if not cfg["bottleneck"]:
                key = conv(f"{p}.conv1", width, prev_c, 3, key)
                key = conv(f"{p}.conv2", width, width, 3, key)
                params[f"{p}.conv2.w"] = params[f"{p}.conv2.w"] * fixup
                if stride != 1 or prev_c != width:
                    key = conv(f"{p}.proj", width, prev_c, 1, key)
                prev_c = width
            else:
                out_c = width * 2
                key = conv(f"{p}.conv1", width, prev_c, 1, key)
                key = conv(f"{p}.conv2", width, width, 3, key)
                key = conv(f"{p}.conv3", out_c, width, 1, key)
                params[f"{p}.conv3.w"] = params[f"{p}.conv3.w"] * fixup
                if stride != 1 or prev_c != out_c:
                    key = conv(f"{p}.proj", out_c, prev_c, 1, key)
                prev_c = out_c
    feat = cfg["widths"][3] * (2 if cfg["bottleneck"] else 1)
    k1, key = jax.random.split(key)
    params["fc.w"] = jax.random.normal(k1, (classes, feat), jnp.float32) * np.sqrt(1.0 / feat)
    params["fc.b"] = jnp.zeros((classes,), jnp.float32)
    return params


def forward(params: dict, x, name: str, conv_impl=None):
    """conv_impl(x, w, pad) is used for 3×3 stride-1 convs (the layers the
    paper accelerates); strided and 1×1 convs always use XLA's conv."""
    cfg = CONFIGS[name]

    def conv(pname, x, stride, pad):
        w = params[f"{pname}.w"]
        b = params[f"{pname}.b"]
        r = w.shape[2]
        if conv_impl is not None and r == 3 and stride == 1:
            y = conv_impl(x, w, pad)
        else:
            y = conv2d_ref(x, w, pad=pad, stride=stride)
        return y + b[None, :, None, None]

    x = jax.nn.relu(conv("stem", x, 1, 1))
    prev_c = cfg["widths"][0]
    for si, (blocks, width) in enumerate(zip(cfg["stages"], cfg["widths"])):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            p = f"s{si}b{bi}"
            if not cfg["bottleneck"]:
                h = jax.nn.relu(conv(f"{p}.conv1", x, stride, 1))
                h = conv(f"{p}.conv2", h, 1, 1)
                sc = conv(f"{p}.proj", x, stride, 0) if (stride != 1 or prev_c != width) else x
                x = jax.nn.relu(h + sc)
                prev_c = width
            else:
                out_c = width * 2
                h = jax.nn.relu(conv(f"{p}.conv1", x, 1, 0))
                h = jax.nn.relu(conv(f"{p}.conv2", h, stride, 1))
                h = conv(f"{p}.conv3", h, 1, 0)
                sc = conv(f"{p}.proj", x, stride, 0) if (stride != 1 or prev_c != out_c) else x
                x = jax.nn.relu(h + sc)
                prev_c = out_c
    x = jnp.mean(x, axis=(2, 3))  # global average pool
    return x @ params["fc.w"].T + params["fc.b"]
