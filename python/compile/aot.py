"""AOT export: lower the JAX models to HLO **text** for the Rust runtime.

HLO text (not `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
`xla` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Exports, per trained model:
  <model>_b{B}.hlo.txt       forward pass, XLA-native convs, batch B
  <model>_sfc_b{B}.hlo.txt   forward pass with the Pallas SFC-6(7×7,3×3)
                             kernel on every 3×3 stride-1 conv — the
                             artifact that proves L1⊂L2⊂L3 composition
plus a standalone conv-layer pair for kernel-level benchmarking:
  conv_sfc.hlo.txt / conv_direct.hlo.txt

Usage: python -m compile.aot [--models resnet18] [--batches 1,8]
"""

from __future__ import annotations

import argparse
import functools
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import algos, model
from .kernels import sfc as sfc_kernel


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def load_weights(path: str) -> dict:
    """Read SFCW weights back into a params dict."""
    params = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"SFCW"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            params[name] = jnp.asarray(data)
    return params


def export(fn, example, path: str) -> None:
    lowered = jax.jit(fn).lower(example)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)/1e6:.1f} MB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="resnet18")
    ap.add_argument("--batches", default="1,8")
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    algo = algos.sfc_7x7_3x3()
    sfc_impl = functools.partial(sfc_kernel.sfc_conv2d, algo=algo)

    for name in args.models.split(","):
        wpath = os.path.join(out, f"{name}.w32")
        params = load_weights(wpath)
        for b in [int(x) for x in args.batches.split(",")]:
            spec = jnp.zeros((b, 3, 32, 32), jnp.float32)

            def fwd_direct(x, params=params, name=name):
                return (model.forward(params, x, name),)

            export(fwd_direct, spec, os.path.join(out, f"{name}_b{b}.hlo.txt"))

            def fwd_sfc(x, params=params, name=name):
                return (
                    model.forward(
                        params,
                        x,
                        name,
                        conv_impl=lambda x, w, pad: sfc_impl(x, w, pad=pad),
                    ),
                )

            export(fwd_sfc, spec, os.path.join(out, f"{name}_sfc_b{b}.hlo.txt"))

    # standalone conv layer (kernel benchmarking from Rust)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 64, 3, 3), jnp.float32) * 0.1
    spec = jnp.zeros((1, 64, 28, 28), jnp.float32)
    export(lambda x: (sfc_kernel.sfc_conv2d(x, w, algo, pad=1),), spec,
           os.path.join(out, "conv_sfc.hlo.txt"))
    from .kernels.ref import conv2d_ref

    export(lambda x: (conv2d_ref(x, w, pad=1),), spec, os.path.join(out, "conv_direct.hlo.txt"))


if __name__ == "__main__":
    main()
