"""Pure-jnp correctness oracles for the Pallas kernels.

These are the build-time ground truth: the Pallas SFC kernel and the full
tiled SFC convolution are asserted against `conv2d_ref` (XLA's own
convolution) in pytest before anything is AOT-exported.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, pad: int = 1, stride: int = 1):
    """NCHW correlation with OIHW weights — the semantics every conv in
    this project implements (matches the Rust engine's conv2d_direct)."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def freq_matmul_ref(v, u):
    """Reference for the transform-domain hot spot: per-frequency channel
    GEMM. v: [T2, tiles, IC], u: [T2, IC, OC] -> [T2, tiles, OC]."""
    return jnp.einsum("fti,fio->fto", v, u)


def sfc_conv2d_ref(x, w, algo, pad: int = 1):
    """Tiled SFC convolution implemented with plain jnp einsums (no
    Pallas) — bit-comparable oracle for the kernel path."""
    bt = jnp.asarray(algo.bt, dtype=x.dtype)
    g = jnp.asarray(algo.g, dtype=x.dtype)
    at = jnp.asarray(algo.at, dtype=x.dtype)
    n, ic, h, wid = x.shape
    oc = w.shape[0]
    m, l, r = algo.m, algo.l, algo.r
    oh, ow = h + 2 * pad - r + 1, wid + 2 * pad - r + 1
    ty, tx = -(-oh // m), -(-ow // m)
    # pad so every tile is full
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (pad, ty * m + l - pad - h), (pad, tx * m + l - pad - wid))
    )
    # gather overlapping tiles [n, ic, ty, tx, l, l]
    tiles = jnp.stack(
        [
            jnp.stack(
                [xp[:, :, i * m : i * m + l, j * m : j * m + l] for j in range(tx)], axis=2
            )
            for i in range(ty)
        ],
        axis=2,
    )
    # V = Bt · tile · B
    v = jnp.einsum("ai,bj,ncyxij->ncyxab", bt, bt, tiles)
    # U = G · w · Gt
    u = jnp.einsum("ai,bj,ocij->ocab", g, g, w)
    # element-wise product + channel reduction
    p = jnp.einsum("ncyxab,ocab->noyxab", v, u)
    # Y = At · p · A
    y = jnp.einsum("ma,kb,noyxab->noyxmk", at, at, p)
    # scatter tiles back
    y = y.transpose(0, 1, 2, 4, 3, 5).reshape(n, oc, ty * m, tx * m)
    return y[:, :, :oh, :ow]
