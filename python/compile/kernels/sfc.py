"""Layer-1: the SFC transform-domain Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA
datapath becomes, on TPU, a per-frequency batched channel-GEMM — exactly
the MXU's native shape. The SFT transforms themselves are constant ±1/0
matmuls that XLA lowers to fused adds around the kernel, so the Pallas
kernel owns the hot spot: for each transform point (u,v) of the T×T grid,

    P[uv] = V[uv] @ U[uv]        # [tiles×IC] @ [IC×OC]

with the grid iterating over frequencies and tile blocks; BlockSpec
streams the [tiles, IC] activations and [IC, OC] weights HBM→VMEM per
frequency. interpret=True everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU perf is estimated analytically in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _freq_matmul_kernel(v_ref, u_ref, o_ref):
    """One (frequency, tile-block) step: o = v @ u."""
    o_ref[...] = jnp.dot(
        v_ref[...], u_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_tiles",))
def freq_matmul(v, u, block_tiles: int = 128):
    """Per-frequency channel GEMM via Pallas.

    v: [T2, tiles, IC]  transformed input tiles (frequency-major)
    u: [T2, IC, OC]     transformed weights
    returns [T2, tiles, OC]
    """
    t2, tiles, ic = v.shape
    _, _, oc = u.shape
    bt = min(block_tiles, tiles)
    grid = (t2, -(-tiles // bt))
    return pl.pallas_call(
        _freq_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bt, ic), lambda f, t: (f, t, 0)),
            pl.BlockSpec((None, ic, oc), lambda f, t: (f, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bt, oc), lambda f, t: (f, t, 0)),
        out_shape=jax.ShapeDtypeStruct((t2, tiles, oc), jnp.float32),
        interpret=True,
    )(v, u)


def transform_weights(w, algo):
    """U = G·w·Gᵀ, reshaped frequency-major [T², IC, OC]."""
    g = jnp.asarray(algo.g, dtype=w.dtype)
    u = jnp.einsum("ai,bj,ocij->abco", g, g, w)  # [T,T,IC,OC]
    t = algo.t
    return u.reshape(t * t, w.shape[1], w.shape[0])


def sfc_conv2d(x, w, algo, pad: int = 1, block_tiles: int = 128):
    """Full tiled SFC convolution with the Pallas hot spot.

    x: [N, IC, H, W] · w: [OC, IC, R, R] → [N, OC, H', W'] (stride 1).
    """
    bt_m = jnp.asarray(algo.bt, dtype=x.dtype)
    at_m = jnp.asarray(algo.at, dtype=x.dtype)
    n, ic, h, wid = x.shape
    oc = w.shape[0]
    m, l, r, t = algo.m, algo.l, algo.r, algo.t
    oh, ow = h + 2 * pad - r + 1, wid + 2 * pad - r + 1
    ty, tx = -(-oh // m), -(-ow // m)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (pad, ty * m + l - pad - h), (pad, tx * m + l - pad - wid))
    )
    tiles = jnp.stack(
        [
            jnp.stack(
                [xp[:, :, i * m : i * m + l, j * m : j * m + l] for j in range(tx)], axis=2
            )
            for i in range(ty)
        ],
        axis=2,
    )  # [n, ic, ty, tx, l, l]
    # input transform (addition network — fused by XLA)
    v = jnp.einsum("ai,bj,ncyxij->abnyxc", bt_m, bt_m, tiles)  # [T,T,n,ty,tx,ic]
    v = v.reshape(t * t, n * ty * tx, ic)
    u = transform_weights(w, algo)  # [T2, ic, oc]
    p = freq_matmul(v, u, block_tiles=block_tiles)  # [T2, n·ty·tx, oc]
    p = p.reshape(t, t, n, ty, tx, oc)
    y = jnp.einsum("ma,kb,abnyxo->noyxmk", at_m, at_m, p)
    y = y.transpose(0, 1, 2, 4, 3, 5).reshape(n, oc, ty * m, tx * m)
    return y[:, :, :oh, :ow]
