"""L2 correctness: model topology, parameter naming parity with the Rust
engine, and SFC-vs-direct forward agreement."""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import algos, model
from compile.kernels import sfc as sfc_kernel


@pytest.fixture(scope="module")
def rn18_params():
    return model.init_params("resnet18", jax.random.PRNGKey(0))


def test_forward_shape(rn18_params):
    x = jnp.zeros((2, 3, 32, 32), jnp.float32)
    y = model.forward(rn18_params, x, "resnet18")
    assert y.shape == (2, 10)


def test_param_names_match_rust_convention(rn18_params):
    # stem + s{si}b{bi}.conv{1,2} + projections + fc
    assert "stem.w" in rn18_params and "stem.b" in rn18_params
    assert "s0b0.conv1.w" in rn18_params
    assert "s1b0.proj.w" in rn18_params  # stride-2 stage entry needs projection
    assert "s0b0.proj.w" not in rn18_params  # same-shape block has none
    assert "fc.w" in rn18_params
    # conv count parity with rust: 20 convs for resnet18-mini
    n_convs = sum(1 for k in rn18_params if k.endswith(".w") and k != "fc.w")
    assert n_convs == 20


def test_resnet50_bottleneck_params():
    params = model.init_params("resnet50", jax.random.PRNGKey(1))
    n_convs = sum(1 for k in params if k.endswith(".w") and k != "fc.w")
    assert n_convs == 53
    assert params["s0b0.conv3.w"].shape == (32, 16, 1, 1)  # expansion 2


def test_sfc_forward_matches_direct(rn18_params):
    algo = algos.sfc_7x7_3x3()
    impl = functools.partial(sfc_kernel.sfc_conv2d, algo=algo)
    x = jnp.asarray(np.random.default_rng(5).standard_normal((1, 3, 32, 32)), jnp.float32)
    y_direct = model.forward(rn18_params, x, "resnet18")
    y_sfc = model.forward(
        rn18_params, x, "resnet18", conv_impl=lambda x, w, pad: impl(x, w, pad=pad)
    )
    np.testing.assert_allclose(np.asarray(y_sfc), np.asarray(y_direct), atol=1e-3)


def test_weight_round_trip(tmp_path, rn18_params):
    from compile.aot import load_weights
    from compile.train import save_weights

    p = tmp_path / "w.w32"
    save_weights(rn18_params, str(p))
    back = load_weights(str(p))
    assert set(back) == set(rn18_params)
    np.testing.assert_array_equal(np.asarray(back["stem.w"]), np.asarray(rn18_params["stem.w"]))
