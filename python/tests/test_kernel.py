"""L1 correctness: the Pallas SFC kernel vs the pure-jnp oracle vs XLA's
own convolution — the CORE correctness signal of the compile path."""

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import algos
from compile.kernels import ref, sfc

ALGO_NAMES = ["sfc-6_7x7_3x3_", "sfc-6_6x6_3x3_", "sfc-4_4x4_3x3_", "wino_4x4_3x3_"]


@pytest.fixture(scope="module", params=ALGO_NAMES)
def algo(request):
    return algos.load(request.param)


def rand(shape, seed, scale=1.0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape), jnp.float32) * scale


class TestMatrices:
    def test_1d_exactness(self, algo):
        rng = np.random.default_rng(3)
        x = rng.integers(-8, 9, size=algo.l).astype(np.float64)
        f = rng.integers(-8, 9, size=algo.r).astype(np.float64)
        z = algo.at @ ((algo.g @ f) * (algo.bt @ x))
        want = np.array([(f * x[k : k + algo.r]).sum() for k in range(algo.m)])
        np.testing.assert_allclose(z, want, atol=1e-9)

    def test_bt_is_addition_network(self, algo):
        if algo.name.startswith("SFC"):
            assert np.abs(algo.bt).max() <= 2.0
            assert np.allclose(algo.bt, np.round(algo.bt))

    def test_shapes(self, algo):
        assert algo.bt.shape == (algo.t, algo.l)
        assert algo.g.shape == (algo.t, algo.r)
        assert algo.at.shape == (algo.m, algo.t)
        assert algo.l == algo.m + algo.r - 1


def tol(algo):
    # Winograd's ill-conditioned transforms lose more f32 bits (that is
    # the paper's point); SFC stays near direct-conv accuracy.
    return 1e-3 if algo.name.startswith("Wino") else 2e-5


class TestOracle:
    def test_sfc_ref_matches_xla_conv(self, algo):
        x = rand((2, 3, 14, 14), 10)
        w = rand((4, 3, 3, 3), 11, 0.3)
        want = ref.conv2d_ref(x, w, pad=1)
        got = ref.sfc_conv2d_ref(x, w, algo, pad=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol(algo))

    def test_no_padding(self, algo):
        x = rand((1, 2, 13, 13), 12)
        w = rand((2, 2, 3, 3), 13, 0.3)
        want = ref.conv2d_ref(x, w, pad=0)
        got = ref.sfc_conv2d_ref(x, w, algo, pad=0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol(algo))


class TestPallas:
    def test_kernel_matches_oracle(self, algo):
        x = rand((2, 4, 14, 14), 20)
        w = rand((5, 4, 3, 3), 21, 0.3)
        want = ref.conv2d_ref(x, w, pad=1)
        got = sfc.sfc_conv2d(x, w, algo, pad=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol(algo))

    def test_freq_matmul_vs_einsum(self):
        v = rand((9, 17, 8), 30)
        u = rand((9, 8, 6), 31)
        got = sfc.freq_matmul(v, u, block_tiles=8)
        want = ref.freq_matmul_ref(v, u)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    @settings(max_examples=12, deadline=None)
    @given(
        n=st.integers(1, 3),
        ic=st.integers(1, 6),
        oc=st.integers(1, 6),
        hw=st.integers(7, 20),
        seed=st.integers(0, 2**31),
    )
    def test_kernel_shape_sweep(self, n, ic, oc, hw, seed):
        """Hypothesis sweep over batch/channel/spatial shapes."""
        algo = algos.sfc_7x7_3x3()
        x = rand((n, ic, hw, hw), seed)
        w = rand((oc, ic, 3, 3), seed + 1, 0.3)
        want = ref.conv2d_ref(x, w, pad=1)
        got = sfc.sfc_conv2d(x, w, algo, pad=1)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)

    @settings(max_examples=8, deadline=None)
    @given(
        t2=st.integers(1, 10),
        tiles=st.integers(1, 40),
        ic=st.integers(1, 16),
        oc=st.integers(1, 16),
        block=st.integers(1, 64),
        seed=st.integers(0, 2**31),
    )
    def test_freq_matmul_block_sweep(self, t2, tiles, ic, oc, block, seed):
        """The Pallas grid must be correct for every block size, including
        ragged tile tails."""
        v = rand((t2, tiles, ic), seed)
        u = rand((t2, ic, oc), seed + 1)
        got = sfc.freq_matmul(v, u, block_tiles=block)
        want = ref.freq_matmul_ref(v, u)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_dtype_bf16(self):
        """bf16 inputs run (MXU-native dtype) with loose tolerance."""
        algo = algos.sfc_7x7_3x3()
        x = rand((1, 4, 14, 14), 40).astype(jnp.bfloat16)
        w = rand((4, 4, 3, 3), 41, 0.3).astype(jnp.bfloat16)
        want = ref.conv2d_ref(x.astype(jnp.float32), w.astype(jnp.float32), pad=1)
        got = sfc.sfc_conv2d(x.astype(jnp.float32), w.astype(jnp.float32), algo, pad=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2)
